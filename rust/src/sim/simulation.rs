//! The simulation driver: the master event loop over a simulated fleet.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::allocation::WorkerId;
use crate::client::{ClientState, DeviceClass, SimClient};
use crate::coordinator::{Master, MasterConfig, MasterState, Payload, ReducePolicy, Submission};
use crate::data::{DataServer, SharedSample, SynthSpec, Synthesizer};
use crate::faults::{FaultPlan, FaultProfile};
use crate::model::ModelSpec;
use crate::rng::Pcg32;
use crate::runtime::{BatchBuilder, Compute};
use crate::trace::{ArgValue, TraceHandle, Track};

use super::RunReport;

/// Scripted fleet-membership events (churn).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ChurnEvent {
    /// A new device of this class joins at the given iteration boundary.
    Join(DeviceClass),
    /// The given worker closes its tab at the iteration boundary.
    Leave(WorkerId),
}

/// Simulation configuration for one training run.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Model name from the manifest (`mnist_conv`, ...).
    pub model: String,
    /// Initial fleet (all join at iteration 0).
    pub fleet: Vec<DeviceClass>,
    /// Corpus sizes (paper: MNIST 60k train / 10k test).
    pub train_size: usize,
    pub test_size: usize,
    pub iterations: u64,
    pub master: MasterConfig,
    /// Evaluate the test set every k iterations (0 = never) — the paper's
    /// tracker worker cadence.
    pub track_every: u64,
    /// Global compute-rate multiplier (scales every device's vectors/sec;
    /// used to trade sim fidelity against sandbox runtime — the shape of
    /// the figures is invariant to it, see DESIGN.md).
    pub power_scale: f64,
    /// Client cache budget bytes (paper practical limit: 100 MB).
    pub cache_budget: u64,
    pub seed: u64,
    /// Scripted churn: iteration → events applied at its start.
    pub churn: BTreeMap<u64, Vec<ChurnEvent>>,
    /// Fault-injection profile, compiled against `seed` into a
    /// [`FaultPlan`] (inert by default — see `faults::FaultProfile`).
    pub faults: FaultProfile,
}

impl SimConfig {
    /// The paper's §3.5 scaling-experiment setup: N LAN workstations,
    /// T = 4 s, synthetic-MNIST 60k/10k, AdaGrad, capacity 3000.
    pub fn paper_scaling(n_workstations: usize, spec: &ModelSpec) -> Self {
        Self {
            model: spec.name.clone(),
            fleet: vec![DeviceClass::Workstation; n_workstations],
            train_size: 60_000,
            test_size: 10_000,
            iterations: 100,
            master: MasterConfig {
                param_count: spec.param_count,
                iter_duration_s: 4.0,
                ..Default::default()
            },
            track_every: 0,
            power_scale: 1.0,
            cache_budget: 100 << 20,
            seed: 1,
            churn: BTreeMap::new(),
            faults: FaultProfile::none(),
        }
    }
}

/// Complete deterministic state of a running simulation at an iteration
/// boundary — the storage plane's checkpoint payload.  Everything *not*
/// here (corpus, test set, batch builder, compute backend) is rebuilt
/// deterministically from `(SimConfig, ModelSpec)` by `Simulation::new`.
#[derive(Debug, Clone, PartialEq)]
pub struct SimState {
    pub master: MasterState,
    pub clients: Vec<ClientState>,
    pub next_worker_id: WorkerId,
    /// Fleet RNG `(state, inc)` — device sampling and link jitter resume
    /// mid-stream.
    pub rng: (u64, u64),
}

/// A running simulation.
pub struct Simulation<'c> {
    cfg: SimConfig,
    spec: ModelSpec,
    compute: &'c mut dyn Compute,
    master: Master,
    clients: BTreeMap<WorkerId, SimClient>,
    server: DataServer,
    /// Tracker-mode test corpus, pre-shared for batch assembly (built
    /// once — evaluations never re-clone samples).
    test_set: Vec<SharedSample>,
    batch: BatchBuilder,
    rng: Pcg32,
    next_worker_id: WorkerId,
    /// Fault schedule compiled from `cfg.faults` against `cfg.seed` —
    /// stateless, so capture/restore needs no extra fields.
    faults: FaultPlan,
    /// Trace plane (off by default); client-side compute/upload spans are
    /// emitted here, master-side spans by the master itself.
    trace: TraceHandle,
    trace_pid: u32,
}

impl<'c> Simulation<'c> {
    /// Build the world: synthesize the corpora, upload the training set to
    /// the data server, register its indices with the master, spawn the
    /// initial fleet.
    pub fn new(cfg: SimConfig, spec: ModelSpec, compute: &'c mut dyn Compute) -> Self {
        assert_eq!(spec.param_count, cfg.master.param_count, "spec/master dim");
        let rng = Pcg32::new(cfg.seed);

        // Corpus (shape per model input).
        let synth_spec = match spec.input.as_slice() {
            [32, 32, 3] => SynthSpec::cifar(cfg.seed ^ 0xDA7A),
            _ => SynthSpec::mnist(cfg.seed ^ 0xDA7A),
        };
        let synth = Synthesizer::new(synth_spec);
        let mut server = DataServer::new();
        server.upload_samples(synth.corpus(cfg.train_size));
        // Test corpus: disjoint sample indices (offset stream).
        let test_set: Vec<SharedSample> = (0..cfg.test_size)
            .map(|i| {
                std::sync::Arc::new(synth.sample(
                    (i % synth_spec.classes as usize) as u8,
                    (cfg.train_size + i) as u64,
                ))
            })
            .collect();

        let params = crate::model::init_params(&spec, cfg.seed);
        let mut master = Master::new(cfg.master.clone(), params);
        master.register_data(cfg.train_size);

        let batch = BatchBuilder::new(spec.batch_size, spec.input_len());
        let faults = FaultPlan::new(cfg.faults.clone(), cfg.seed);
        let mut sim = Self {
            faults,
            cfg,
            spec,
            compute,
            master,
            clients: BTreeMap::new(),
            server,
            test_set,
            batch,
            rng,
            next_worker_id: 1,
            trace: TraceHandle::off(),
            trace_pid: 0,
        };
        let fleet = sim.cfg.fleet.clone();
        for class in fleet {
            sim.spawn_client(class);
        }
        sim.rng = Pcg32::new(sim.cfg.seed ^ 0x5EED);
        sim
    }

    pub fn master(&self) -> &Master {
        &self.master
    }

    /// The compiled fault schedule (tests pin its digest for equal-seed
    /// determinism).
    pub fn fault_plan(&self) -> &FaultPlan {
        &self.faults
    }

    /// Attach a trace handle for this run; `pid` names the project on the
    /// shared timeline (the cosim passes each training sim its ProjectId).
    pub fn set_trace(&mut self, trace: TraceHandle, pid: u32) {
        self.master.set_trace(trace.clone(), pid);
        self.trace = trace;
        self.trace_pid = pid;
    }

    /// Mutable master access (closure-resume paths and tests).
    pub fn master_mut_for_test(&mut self) -> &mut Master {
        &mut self.master
    }

    /// Mutable master access for the storage plane (attaching a WAL,
    /// enabling replay digests, syncing at checkpoint boundaries).
    pub fn master_mut(&mut self) -> &mut Master {
        &mut self.master
    }

    /// Capture the full deterministic state at the current iteration
    /// boundary (between `step` calls).
    pub fn capture_state(&self) -> SimState {
        SimState {
            master: self.master.export_state(),
            // BTreeMap order → client list is id-ascending and stable.
            clients: self.clients.values().map(SimClient::export_state).collect(),
            next_worker_id: self.next_worker_id,
            rng: self.rng.state(),
        }
    }

    /// Restore a state captured by [`Simulation::capture_state`] onto a
    /// freshly built simulation of the *same* `(SimConfig, ModelSpec)`.
    /// Subsequent `step` calls are bitwise-identical to the original run.
    pub fn restore_state(&mut self, st: SimState) {
        let iteration = st.master.iteration;
        self.master.import_state(st.master);
        self.clients = st
            .clients
            .into_iter()
            .map(|cs| {
                (
                    cs.id,
                    SimClient::from_state(cs, self.cfg.cache_budget, &self.server),
                )
            })
            .collect();
        self.next_worker_id = st.next_worker_id;
        self.rng = Pcg32::from_state(st.rng.0, st.rng.1);
        // Churn scripted before the restore point already fired in the
        // captured state; only boundary-or-later events may fire again.
        self.cfg.churn.retain(|k, _| *k >= iteration);
    }

    /// Resume from a research closure: replace the parameter vector.
    pub fn load_params(&mut self, params: Vec<f32>) {
        self.master.set_params(params);
    }

    pub fn n_clients(&self) -> usize {
        self.clients.len()
    }

    /// Join a new device: master allocation + client-side assignment.
    pub fn spawn_client(&mut self, class: DeviceClass) -> WorkerId {
        let id = self.next_worker_id;
        self.next_worker_id += 1;
        let mut profile = class.sample_profile(&mut self.rng);
        profile.power_vps *= self.cfg.power_scale;
        let mut client = SimClient::new(id, profile, self.cfg.cache_budget, &mut self.rng);
        let delta = self.master.worker_join(id);
        for (w, ids) in &delta.assigned {
            if *w == id {
                client.assign(ids);
            } else if let Some(c) = self.clients.get_mut(w) {
                c.assign(ids);
            }
        }
        for (w, ids) in &delta.revoked {
            if let Some(c) = self.clients.get_mut(w) {
                c.revoke(ids);
            }
        }
        self.clients.insert(id, client);
        id
    }

    /// A client closes its tab: master reallocates, survivors pick up ids.
    pub fn remove_client(&mut self, id: WorkerId) {
        if self.clients.remove(&id).is_none() {
            return;
        }
        let delta = self.master.worker_leave(id);
        for (w, ids) in &delta.assigned {
            if let Some(c) = self.clients.get_mut(w) {
                c.assign(ids);
            }
        }
    }

    /// Run `iterations` master-loop iterations; returns the report.
    pub fn run(&mut self) -> Result<RunReport> {
        for _ in 0..self.cfg.iterations {
            self.step()?;
        }
        Ok(RunReport::from_timeline(
            self.master.timeline().clone(),
            self.clients.len(),
        ))
    }

    /// One full master-loop iteration (steps a–e of §3.3).
    pub fn step(&mut self) -> Result<()> {
        let iter = self.master.iteration();

        // -- scripted churn at the iteration boundary (new clients "must
        //    wait until the end of an iteration before joining", §3.2)
        if let Some(events) = self.cfg.churn.remove(&iter) {
            for ev in events {
                match ev {
                    ChurnEvent::Join(class) => {
                        self.spawn_client(class);
                    }
                    ChurnEvent::Leave(w) => self.remove_client(w),
                }
            }
        }

        // Fleet-size gauge after churn settles — the counter track that
        // makes join/leave storms visible next to the iteration spans.
        if self.trace.is_on() {
            self.trace.counter(
                Track::master(self.trace_pid),
                "train/fleet",
                self.master.now_ms(),
                &[("clients", self.clients.len() as f64)],
            );
        }

        // -- step a: background data downloads (one iteration's worth of
        //    XHR at each client's downlink rate).  A storm-disconnected
        //    client does nothing this iteration — no downloads, no
        //    training, no upload; it reappears when the burst ends.
        let iter_ms = self.master.iter_ms();
        let mut disconnected = 0u64;
        for (id, client) in self.clients.iter_mut() {
            if self.faults.disconnected(*id, iter) {
                continue;
            }
            let budget = (client.link.bandwidth_bytes_per_ms() * iter_ms) as u64;
            let (got, _bytes) = client.download_step(&self.server, budget);
            for data_id in got {
                self.master.mark_cached(*id, data_id);
            }
        }

        // -- map step: every trainer computes under its scheduled budget.
        //    The broadcast parameters are borrowed straight from the
        //    master (no per-iteration copy), and dense gradients move
        //    into Arc payloads unchanged — the ingest path never clones
        //    a gradient.
        let params = self.master.params();
        let policy = self.master.config().policy;
        let mut submissions = Vec::with_capacity(self.clients.len());
        let (mut corrupted, mut dropped, mut duplicated, mut slowed) = (0u64, 0u64, 0u64, 0u64);
        for (id, client) in self.clients.iter_mut() {
            if self.faults.disconnected(*id, iter) {
                disconnected += 1;
                continue;
            }
            let budget_ms = self.master.work_budget_ms(*id);
            let Some(mut out) = client.train(self.compute, &self.spec, params, budget_ms)?
            else {
                continue;
            };
            // Straggler injection: same work, stretched wall time (a
            // backgrounded tab / thermally throttled device) — the barrier
            // and the latency monitor see the overrun.
            let slowdown = self.faults.slowdown_for(client.profile.class, *id);
            if slowdown > 1.0 {
                out.compute_ms *= slowdown;
                slowed += 1;
            }
            // Hostile-gradient injection, before the payload is built so
            // sparsification carries the corrupted coordinates too.
            if self.faults.corrupt(&mut out.grad_sum, *id) {
                corrupted += 1;
            }
            let payload = match policy {
                ReducePolicy::PartialSync { keep_fraction } => {
                    Payload::sparsify(&out.grad_sum, keep_fraction)
                }
                _ => Payload::dense(out.grad_sum),
            };
            let bytes = payload.bytes() + 96; // envelope: ids, counts, framing
            // Upload with fault-plane drop + retry/backoff: give up once a
            // resend would start beyond the next iteration boundary (the
            // submission is lost; quorum/carryover absorb the gap).
            let deadline_ms = out.compute_ms + 2.0 * iter_ms;
            let Some(uplink) =
                client.upload_ms(bytes, out.compute_ms, deadline_ms, &self.faults, iter)
            else {
                dropped += 1;
                continue;
            };
            if self.trace.is_on() {
                let t0 = self.master.now_ms();
                let track = Track::worker(self.trace_pid, *id as u32);
                self.trace.span(
                    track,
                    "train",
                    "compute",
                    t0,
                    t0 + out.compute_ms,
                    &[
                        ("examples", ArgValue::U64(out.examples)),
                        ("budget_ms", ArgValue::F64(budget_ms)),
                    ],
                );
                self.trace.span(
                    track,
                    "train",
                    "upload",
                    t0 + out.compute_ms,
                    t0 + out.compute_ms + uplink,
                    &[("bytes", ArgValue::U64(bytes))],
                );
            }
            // Duplicate delivery: the same payload arrives again on its
            // own jitter draw (dense payloads share the Arc).  The
            // master's sanitation gate keeps exactly one.
            let dup = self.faults.duplicated(*id, iter);
            let dup_payload = dup.then(|| payload.clone());
            submissions.push(Submission {
                worker: *id,
                payload,
                examples: out.examples,
                vectors: out.examples,
                loss_sum: out.loss_sum,
                send_offset_ms: out.compute_ms + uplink,
                bytes,
            });
            if let Some(payload) = dup_payload {
                duplicated += 1;
                let extra = client.link.sample_latency_ms(&mut client.rng)
                    + client.link.transmit_ms(bytes);
                submissions.push(Submission {
                    worker: *id,
                    payload,
                    examples: out.examples,
                    vectors: out.examples,
                    loss_sum: out.loss_sum,
                    send_offset_ms: out.compute_ms + extra,
                    bytes,
                });
            }
        }

        if self.trace.is_on() && self.faults.is_active() {
            self.trace.counter(
                Track::master(self.trace_pid),
                "train/faults-injected",
                self.master.now_ms(),
                &[
                    ("disconnected", disconnected as f64),
                    ("corrupted", corrupted as f64),
                    ("dropped", dropped as f64),
                    ("duplicated", duplicated as f64),
                    ("stragglers", slowed as f64),
                ],
            );
        }

        // -- steps c/d/e at the master
        let outcome = self.master.finish_iteration(submissions);
        for (w, delta) in &outcome.evicted {
            // The master already reallocated the evicted worker's data;
            // mirror it fleet-side like a forced tab close.
            self.clients.remove(w);
            for (aw, ids) in &delta.assigned {
                if let Some(c) = self.clients.get_mut(aw) {
                    c.assign(ids);
                }
            }
        }
        for (w, delta) in &outcome.shed_deltas {
            if let Some(c) = self.clients.get_mut(w) {
                for (dw, ids) in &delta.revoked {
                    debug_assert_eq!(dw, w);
                    c.revoke(ids);
                }
            }
            for (aw, ids) in &delta.assigned {
                if let Some(c) = self.clients.get_mut(aw) {
                    c.assign(ids);
                }
            }
        }

        // -- tracking mode (§3.6): tracker worker evaluates the test set
        //    with the freshly broadcast parameters
        if self.cfg.track_every > 0 && (iter + 1) % self.cfg.track_every == 0 {
            let err = self.evaluate_test_error()?;
            self.master.report_test_error(err);
        }
        Ok(())
    }

    /// Tracker-mode evaluation: full pass over the test set (wrap-around
    /// padding to whole microbatches).
    pub fn evaluate_test_error(&mut self) -> Result<f64> {
        let params = self.master.params();
        if self.test_set.is_empty() {
            return Ok(f64::NAN);
        }
        let bsz = self.batch.batch_size();
        let n_batches = self.test_set.len().div_ceil(bsz);
        let mut correct = 0.0f64;
        let mut total = 0usize;
        let mut cursor = 0usize;
        for _ in 0..n_batches {
            cursor = self.batch.fill_cyclic(&self.test_set, cursor);
            let out = self.compute.eval_batch(
                &self.spec.name,
                bsz,
                params,
                self.batch.images(),
                self.batch.labels(),
            )?;
            correct += out.correct as f64;
            total += bsz;
        }
        Ok(1.0 - correct / total as f64)
    }

    /// Current training-set coverage: fraction of registered ids allocated
    /// to some worker (the §3.5 capacity-policy effect behind Fig 5).
    pub fn coverage(&self) -> f64 {
        let total = self.master.allocator().total_data();
        if total == 0 {
            return 0.0;
        }
        self.master.allocator().allocated_count() as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TensorSpec;
    use crate::runtime::ModeledCompute;

    fn toy_spec(batch: usize) -> ModelSpec {
        ModelSpec {
            name: "toy".into(),
            param_count: 8,
            batch_size: batch,
            micro_batches: vec![batch],
            input: vec![28, 28, 1],
            classes: 10,
            tensors: vec![TensorSpec {
                name: "w".into(),
                shape: vec![8],
                offset: 0,
                size: 8,
                fan_in: 4,
            }],
            artifacts: Default::default(),
        }
    }

    fn base_cfg(n: usize, spec: &ModelSpec) -> SimConfig {
        let mut cfg = SimConfig::paper_scaling(n, spec);
        cfg.train_size = 500;
        cfg.test_size = 64;
        cfg.iterations = 5;
        cfg.master.capacity = 100;
        cfg
    }

    #[test]
    fn end_to_end_modeled_run() {
        let spec = toy_spec(16);
        let cfg = base_cfg(4, &spec);
        let mut compute = ModeledCompute { param_count: 8 };
        let mut sim = Simulation::new(cfg, spec, &mut compute);
        assert_eq!(sim.n_clients(), 4);
        let report = sim.run().unwrap();
        assert_eq!(report.timeline.len(), 5);
        assert!(report.power_vps > 0.0, "{}", report.summary());
        assert!(report.total_vectors > 0);
        sim.master().allocator().check_invariants().unwrap();
    }

    #[test]
    fn zero_worker_fleet_runs_to_completion() {
        // A project can exist with data registered but no volunteer ever
        // joining: every iteration is a zero-worker iteration.
        let spec = toy_spec(16);
        let mut cfg = base_cfg(0, &spec);
        cfg.fleet = vec![];
        cfg.iterations = 3;
        let mut compute = ModeledCompute { param_count: 8 };
        let mut sim = Simulation::new(cfg, spec, &mut compute);
        assert_eq!(sim.n_clients(), 0);
        assert_eq!(sim.coverage(), 0.0);
        let report = sim.run().unwrap();
        assert_eq!(report.timeline.len(), 3);
        assert_eq!(report.total_vectors, 0);
        assert!(report.virtual_secs >= 12.0, "time must still advance");
        sim.master().allocator().check_invariants().unwrap();
    }

    #[test]
    fn coverage_grows_with_fleet() {
        let spec = toy_spec(16);
        let mut compute = ModeledCompute { param_count: 8 };
        let cfg = base_cfg(2, &spec); // 2 × 100 capacity of 500 ids
        let sim = Simulation::new(cfg, spec.clone(), &mut compute);
        assert!((sim.coverage() - 0.4).abs() < 1e-9);
        let mut compute2 = ModeledCompute { param_count: 8 };
        let cfg = base_cfg(5, &spec);
        let sim = Simulation::new(cfg, spec, &mut compute2);
        assert!((sim.coverage() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn churn_join_and_leave_mid_run() {
        let spec = toy_spec(16);
        let mut cfg = base_cfg(2, &spec);
        cfg.iterations = 6;
        cfg.churn
            .insert(2, vec![ChurnEvent::Join(DeviceClass::Mobile)]);
        cfg.churn.insert(4, vec![ChurnEvent::Leave(1)]);
        let mut compute = ModeledCompute { param_count: 8 };
        let mut sim = Simulation::new(cfg, spec, &mut compute);
        let report = sim.run().unwrap();
        assert_eq!(sim.n_clients(), 2); // 2 + 1 - 1
        assert_eq!(report.timeline.len(), 6);
        sim.master().allocator().check_invariants().unwrap();
    }

    #[test]
    fn tracking_produces_test_error() {
        let spec = toy_spec(16);
        let mut cfg = base_cfg(2, &spec);
        cfg.track_every = 2;
        let mut compute = ModeledCompute { param_count: 8 };
        let mut sim = Simulation::new(cfg, spec, &mut compute);
        let report = sim.run().unwrap();
        // modeled compute: 10% correct → 0.9 error
        let err = report.final_test_error.unwrap();
        assert!((err - 0.9).abs() < 1e-6, "{err}");
    }

    #[test]
    fn traced_run_emits_client_and_master_spans() {
        let spec = toy_spec(16);
        let cfg = base_cfg(2, &spec);
        let mut compute = ModeledCompute { param_count: 8 };
        let mut sim = Simulation::new(cfg, spec, &mut compute);
        let trace = TraceHandle::recording();
        sim.set_trace(trace.clone(), 3);
        sim.run().unwrap();
        let evs = trace.snapshot();
        assert!(evs.iter().any(|e| e.name == "compute"));
        assert!(evs.iter().any(|e| e.name == "upload"));
        assert!(evs.iter().any(|e| e.name == "iteration"));
        assert!(evs.iter().all(|e| e.track.pid == 3));
        assert_eq!(trace.open_async(), 0, "training emits no async spans");
    }

    #[test]
    fn capture_restore_resumes_bitwise_mid_run() {
        // Reference run: 8 iterations straight through, with churn and
        // jittery links so every piece of state matters.
        let spec = toy_spec(16);
        let mk_cfg = || {
            let mut cfg = base_cfg(3, &spec);
            cfg.fleet = vec![DeviceClass::Mobile, DeviceClass::Laptop, DeviceClass::Mobile];
            cfg.iterations = 8;
            cfg.track_every = 2;
            cfg.churn
                .insert(2, vec![ChurnEvent::Join(DeviceClass::Desktop)]);
            cfg.churn.insert(6, vec![ChurnEvent::Leave(1)]);
            cfg
        };
        let mut compute = ModeledCompute { param_count: 8 };
        let mut reference = Simulation::new(mk_cfg(), spec.clone(), &mut compute);
        let mut mid_state = None;
        for it in 0..8 {
            if it == 4 {
                mid_state = Some(reference.capture_state());
            }
            reference.step().unwrap();
        }

        // Resumed run: fresh world, restore at iteration 4, finish.
        let mut compute2 = ModeledCompute { param_count: 8 };
        let mut resumed = Simulation::new(mk_cfg(), spec, &mut compute2);
        resumed.restore_state(mid_state.unwrap());
        assert_eq!(resumed.master().iteration(), 4);
        for _ in 4..8 {
            resumed.step().unwrap();
        }

        let bits = |m: &Master| {
            m.params().iter().map(|p| p.to_bits()).collect::<Vec<_>>()
        };
        assert_eq!(bits(reference.master()), bits(resumed.master()));
        assert_eq!(
            reference.master().timeline().to_csv(),
            resumed.master().timeline().to_csv()
        );
        assert_eq!(reference.n_clients(), resumed.n_clients());
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = toy_spec(16);
        let run = |seed: u64| {
            let mut cfg = base_cfg(3, &spec);
            // cellular devices: latency jitter shows up in the timeline,
            // making seed-sensitivity observable
            cfg.fleet = vec![DeviceClass::Mobile; 3];
            cfg.seed = seed;
            let mut compute = ModeledCompute { param_count: 8 };
            let mut sim = Simulation::new(cfg, spec.clone(), &mut compute);
            let r = sim.run().unwrap();
            (r.timeline.to_csv(), r.total_vectors)
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn hostile_nan_worker_is_quarantined_and_evicted_mid_sim() {
        // Seed 1 at fraction 0.5 over workers 1..=4 marks worker 1
        // hostile (pinned in faults::tests).  Its NaN uploads must never
        // reach the parameters, and three strikes must remove it from the
        // fleet with its data reallocated.
        let spec = toy_spec(16);
        let mut cfg = base_cfg(4, &spec);
        cfg.iterations = 6;
        cfg.seed = 1;
        cfg.faults = FaultProfile::parse("hostile:0.5:nan").unwrap();
        let mut compute = ModeledCompute { param_count: 8 };
        let mut sim = Simulation::new(cfg, spec, &mut compute);
        sim.run().unwrap();
        assert!(sim.master().params().iter().all(|p| p.is_finite()));
        assert!(sim.n_clients() < 4, "adversary was never evicted");
        sim.master().allocator().check_invariants().unwrap();
    }

    #[test]
    fn storm_profile_completes_with_invariants_and_fault_counters() {
        let spec = toy_spec(16);
        let mut cfg = base_cfg(4, &spec);
        cfg.iterations = 12; // crosses the storm window at iteration 8
        cfg.faults = FaultProfile::parse("storm").unwrap();
        let mut compute = ModeledCompute { param_count: 8 };
        let mut sim = Simulation::new(cfg, spec, &mut compute);
        let trace = TraceHandle::recording();
        sim.set_trace(trace.clone(), 1);
        let report = sim.run().unwrap();
        assert_eq!(report.timeline.len(), 12);
        assert!(sim.master().params().iter().all(|p| p.is_finite()));
        sim.master().allocator().check_invariants().unwrap();
        let evs = trace.snapshot();
        assert!(evs.iter().any(|e| e.name == "train/faults-injected"));
        assert!(evs.iter().any(|e| e.name == "train/quarantined"));
    }
}
