//! Discrete-event simulation binding master + fleet + data server + netsim.
//!
//! Replaces the paper's physical testbed (32 LAN workstations + phones)
//! with a deterministic virtual-clock driver — see DESIGN.md
//! §Substitutions.  Gradient computation can be *real* (PJRT engine; used
//! for Fig 5/8 convergence) or *modeled* (work accounting only; used for
//! the Fig 4 coordination sweep to 96 nodes).  The coordination logic is
//! identical in both modes — it is the same [`Master`].

mod report;
mod simulation;

pub use report::RunReport;
pub use simulation::{ChurnEvent, SimConfig, SimState, Simulation};
