//! Experiment run summaries.

use crate::metrics::Timeline;

/// Summary of one simulated training run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub timeline: Timeline,
    /// Aggregate power — the paper's Fig 4 y-axis (vectors/second).
    pub power_vps: f64,
    /// Mean slave↔master latency across iterations (Fig 4 second axis).
    pub mean_latency_ms: f64,
    /// Final fleet size.
    pub workers: usize,
    /// Completed master-loop iterations (timeline records).
    pub iterations: usize,
    /// Final test error (if tracking ran).
    pub final_test_error: Option<f64>,
    /// Total master ingress/egress bytes.
    pub bytes_up: u64,
    pub bytes_down: u64,
    /// Virtual duration of the run (seconds).
    pub virtual_secs: f64,
    /// Total data vectors processed.
    pub total_vectors: u64,
}

impl RunReport {
    pub fn from_timeline(timeline: Timeline, workers: usize) -> Self {
        let power_vps = timeline.power_vectors_per_sec();
        let mean_latency_ms = timeline.mean_latency_ms();
        let final_test_error = timeline
            .records()
            .iter()
            .filter_map(|r| r.test_error)
            .last();
        let bytes_up = timeline.records().iter().map(|r| r.bytes_up).sum();
        let bytes_down = timeline.records().iter().map(|r| r.bytes_down).sum();
        let virtual_secs = timeline.last().map(|r| r.t_virtual_ms / 1000.0).unwrap_or(0.0);
        let total_vectors = timeline.records().iter().map(|r| r.vectors).sum();
        let iterations = timeline.len();
        Self {
            timeline,
            power_vps,
            mean_latency_ms,
            workers,
            iterations,
            final_test_error,
            bytes_up,
            bytes_down,
            virtual_secs,
            total_vectors,
        }
    }

    /// Test error at (or before) a given iteration — Fig 5's readout.
    pub fn test_error_at(&self, iteration: u64) -> Option<f64> {
        self.timeline.test_error_at(iteration)
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "workers={} iters={} power={:.1} vec/s latency={:.1} ms vectors={} virtual={:.0}s{}",
            self.workers,
            self.iterations,
            self.power_vps,
            self.mean_latency_ms,
            self.total_vectors,
            self.virtual_secs,
            match self.final_test_error {
                Some(e) => format!(" test_err={e:.4}"),
                None => String::new(),
            }
        )
    }
}
