//! Flat parameter-vector math and optimizers.
//!
//! The paper's master broadcasts "an array of model parameters" (§3.3e) and
//! its reduce step "computes a weighted average of gradients from all
//! workers and takes a gradient step using AdaGrad" (§3.6).  The L2 JAX
//! models pack all parameters into a single flat f32 vector, so the entire
//! reduce/update path is dense vector arithmetic over `&[f32]` — this
//! module is the L3 hot path measured in `benches/micro.rs`.  The
//! production merge is [`ShardedAccumulator`] (parameter-sharded across
//! scoped threads, bitwise-identical to the serial [`GradAccumulator`]);
//! see DESIGN.md's reduce-layer section.

mod optimizer;
mod robust;
mod sharded;
mod vecmath;

pub use optimizer::{AdaGrad, Momentum, Optimizer, OptimizerKind, RmsProp, Sgd};
pub use robust::{AggregationMode, RobustCombiner};
pub use sharded::{GradView, ShardedAccumulator};
pub use vecmath::{add_assign, axpy, dot, l2_norm, scale, scaled_copy, GradAccumulator};

#[cfg(test)]
mod tests {
    use super::*;

    /// End-to-end: accumulate two weighted worker gradients, AdaGrad-step,
    /// verify against a hand-computed update.
    #[test]
    fn reduce_then_adagrad_matches_hand_calculation() {
        let mut acc = GradAccumulator::new(3);
        acc.add(&[1.0, 2.0, 3.0], 2); // worker A: 2 examples (sum-grad)
        acc.add(&[3.0, 2.0, 1.0], 2); // worker B: 2 examples
        let g = acc.weighted_average().to_vec(); // (gA+gB)/4
        assert_eq!(g, vec![1.0, 1.0, 1.0]);

        let mut opt = AdaGrad::new(3, 0.1, 1e-8);
        let mut params = vec![0.0f32; 3];
        opt.step(&mut params, &g);
        // h = g², update = lr * g / (sqrt(h)+eps) = 0.1 * 1/1
        for p in &params {
            assert!((p + 0.1).abs() < 1e-5, "{params:?}");
        }
    }
}
