//! Dense f32 vector kernels for the reduce step.
//!
//! These loops are the master's per-iteration cost (the paper's latency
//! knee at 64 nodes comes from the master serially processing gradient
//! messages, §3.5).  The elementwise kernels are written over fixed-width
//! chunks (`LANES` f32 per step) so the inner loop has a compile-time trip
//! count — LLVM turns each chunk into straight-line SIMD with no
//! per-element bounds checks, where the old `zip` loops vectorized only
//! when the optimizer could prove the slices disjoint.  `benches/micro.rs`
//! tracks ns/param and emits the `MasterModel.merge_ns_per_param`
//! calibration (`BENCH_reduce.json`).
//!
//! All kernels are elementwise, so chunking never reorders any individual
//! float operation: results are bitwise-identical to the naive loops (the
//! `dot` reduction keeps a single f64 accumulator for the same reason).

/// Unroll width for the elementwise kernels (one AVX2 f32 register).
const LANES: usize = 8;

/// y += a * x  (the gradient-merge kernel).
#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    let mut yc = y.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (yb, xb) in yc.by_ref().zip(xc.by_ref()) {
        for (yi, xi) in yb.iter_mut().zip(xb) {
            *yi += a * *xi;
        }
    }
    for (yi, xi) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yi += a * *xi;
    }
}

/// y += x.
#[inline]
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    let mut yc = y.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (yb, xb) in yc.by_ref().zip(xc.by_ref()) {
        for (yi, xi) in yb.iter_mut().zip(xb) {
            *yi += *xi;
        }
    }
    for (yi, xi) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yi += *xi;
    }
}

/// x *= a.
#[inline]
pub fn scale(x: &mut [f32], a: f32) {
    let mut xc = x.chunks_exact_mut(LANES);
    for xb in xc.by_ref() {
        for xi in xb.iter_mut() {
            *xi *= a;
        }
    }
    for xi in xc.into_remainder() {
        *xi *= a;
    }
}

/// out = a * x  (scaled copy — the weighted-average write-out kernel).
#[inline]
pub fn scaled_copy(out: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    let mut oc = out.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (ob, xb) in oc.by_ref().zip(xc.by_ref()) {
        for (oi, xi) in ob.iter_mut().zip(xb) {
            *oi = a * *xi;
        }
    }
    for (oi, xi) in oc.into_remainder().iter_mut().zip(xc.remainder()) {
        *oi = a * *xi;
    }
}

/// Dot product (f64 accumulator for stability in norms over ~100k params).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| *x as f64 * *y as f64)
        .sum()
}

/// ‖x‖₂.
#[inline]
pub fn l2_norm(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// Accumulates *sum* gradients from workers along with their example
/// counts, producing the weighted average the paper's reduce step uses
/// (§3.6: "a weighted average of gradients from all workers").
///
/// Workers return Σ-gradients over `n_k` examples; the weighted average is
/// (Σ_k g_k) / (Σ_k n_k) — heterogeneous batch counts are weighted
/// correctly for free.  The buffer is reused across iterations (zero
/// allocation on the hot path).
///
/// This is the single-threaded reference merge; the production reduce path
/// is [`super::ShardedAccumulator`], which is bitwise-equivalent given the
/// same submission order (pinned by `tests/prop_reduce.rs`).
#[derive(Debug, Clone)]
pub struct GradAccumulator {
    sum: Vec<f32>,
    count: u64,
    contributions: u32,
}

impl GradAccumulator {
    pub fn new(dim: usize) -> Self {
        Self {
            sum: vec![0.0; dim],
            count: 0,
            contributions: 0,
        }
    }

    pub fn dim(&self) -> usize {
        self.sum.len()
    }

    /// Merge one worker's sum-gradient over `examples` data vectors.
    pub fn add(&mut self, grad_sum: &[f32], examples: u64) {
        assert_eq!(grad_sum.len(), self.sum.len(), "gradient dim mismatch");
        add_assign(&mut self.sum, grad_sum);
        self.count += examples;
        self.contributions += 1;
    }

    /// Merge a *sparse* partial gradient (index, value) pairs — the paper's
    /// §5 "partial communication of gradients" mitigation.  Values are sums
    /// over the worker's examples, same convention as `add`.
    ///
    /// Indices are validated against `dim()` *before* any entry is merged:
    /// a corrupt message panics with a descriptive error and leaves the
    /// accumulator untouched instead of dying half-merged on a bare
    /// index-out-of-bounds.
    pub fn add_sparse(&mut self, entries: &[(u32, f32)], examples: u64) {
        let dim = self.sum.len();
        if let Some(&(i, _)) = entries.iter().find(|&&(i, _)| i as usize >= dim) {
            panic!("sparse gradient index {i} out of bounds for dim {dim}");
        }
        for &(i, v) in entries {
            self.sum[i as usize] += v;
        }
        self.count += examples;
        self.contributions += 1;
    }

    pub fn examples(&self) -> u64 {
        self.count
    }

    pub fn contributions(&self) -> u32 {
        self.contributions
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// The weighted-average gradient; empty accumulator yields zeros.
    pub fn weighted_average(&self) -> Vec<f32> {
        let mut avg = vec![0.0; self.sum.len()];
        self.weighted_average_into(&mut avg);
        avg
    }

    /// In-place variant writing into a caller-provided buffer (hot path).
    pub fn weighted_average_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.sum.len());
        let inv = if self.count > 0 {
            1.0 / self.count as f32
        } else {
            0.0
        };
        scaled_copy(out, inv, &self.sum);
    }

    /// Reset for the next iteration without freeing the buffer.
    pub fn reset(&mut self) {
        self.sum.fill(0.0);
        self.count = 0;
        self.contributions = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let mut y = vec![1.0, 2.0];
        axpy(&mut y, 2.0, &[10.0, 20.0]);
        assert_eq!(y, vec![21.0, 42.0]);
    }

    #[test]
    fn kernels_cover_chunk_and_remainder() {
        // Lengths straddling the unroll width: chunk body + remainder tail.
        for n in [0, 1, 7, 8, 9, 16, 27] {
            let x: Vec<f32> = (0..n).map(|i| i as f32 + 0.5).collect();
            let mut y = vec![1.0f32; n];
            axpy(&mut y, 2.0, &x);
            for (i, yi) in y.iter().enumerate() {
                assert_eq!(*yi, 1.0 + 2.0 * (i as f32 + 0.5), "axpy n={n} i={i}");
            }
            let mut y = vec![1.0f32; n];
            add_assign(&mut y, &x);
            for (i, yi) in y.iter().enumerate() {
                assert_eq!(*yi, 1.0 + i as f32 + 0.5, "add_assign n={n} i={i}");
            }
            let mut s = x.clone();
            scale(&mut s, 3.0);
            let mut c = vec![0.0f32; n];
            scaled_copy(&mut c, 3.0, &x);
            assert_eq!(s, c, "scale vs scaled_copy n={n}");
        }
    }

    #[test]
    fn dot_and_norm() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_average_respects_counts() {
        let mut acc = GradAccumulator::new(2);
        // worker A: 1 example with grad [1, 0]; worker B: 3 examples, sum [0, 6]
        acc.add(&[1.0, 0.0], 1);
        acc.add(&[0.0, 6.0], 3);
        assert_eq!(acc.weighted_average(), vec![0.25, 1.5]);
        assert_eq!(acc.examples(), 4);
        assert_eq!(acc.contributions(), 2);
    }

    #[test]
    fn empty_average_is_zero() {
        let acc = GradAccumulator::new(3);
        assert!(acc.is_empty());
        assert_eq!(acc.weighted_average(), vec![0.0; 3]);
    }

    #[test]
    fn sparse_equals_dense_on_support() {
        let mut dense = GradAccumulator::new(4);
        dense.add(&[0.0, 5.0, 0.0, -1.0], 2);
        let mut sparse = GradAccumulator::new(4);
        sparse.add_sparse(&[(1, 5.0), (3, -1.0)], 2);
        assert_eq!(dense.weighted_average(), sparse.weighted_average());
    }

    #[test]
    fn reset_reuses_buffer() {
        let mut acc = GradAccumulator::new(2);
        acc.add(&[1.0, 1.0], 1);
        acc.reset();
        assert!(acc.is_empty());
        assert_eq!(acc.weighted_average(), vec![0.0, 0.0]);
    }

    #[test]
    fn into_variant_matches() {
        let mut acc = GradAccumulator::new(3);
        acc.add(&[3.0, 6.0, 9.0], 3);
        let mut out = vec![0.0; 3];
        acc.weighted_average_into(&mut out);
        assert_eq!(out, acc.weighted_average());
    }

    #[test]
    #[should_panic(expected = "dim mismatch")]
    fn dim_mismatch_panics() {
        let mut acc = GradAccumulator::new(2);
        acc.add(&[1.0], 1);
    }

    #[test]
    #[should_panic(expected = "sparse gradient index 9 out of bounds for dim 4")]
    fn corrupt_sparse_index_panics_descriptively() {
        let mut acc = GradAccumulator::new(4);
        acc.add_sparse(&[(1, 2.0), (9, 1.0)], 1);
    }

    #[test]
    fn corrupt_sparse_message_leaves_accumulator_untouched() {
        // Validation happens before any entry is merged: catching the
        // panic must find the accumulator exactly as it was.
        let mut acc = GradAccumulator::new(4);
        acc.add(&[1.0, 2.0, 3.0, 4.0], 2);
        let before = acc.weighted_average();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            acc.add_sparse(&[(0, 5.0), (100, 1.0)], 1);
        }));
        assert!(res.is_err());
        assert_eq!(acc.weighted_average(), before);
        assert_eq!(acc.examples(), 2);
        assert_eq!(acc.contributions(), 1);
    }
}
