//! Dense f32 vector kernels for the reduce step.
//!
//! These loops are the master's per-iteration cost (the paper's latency
//! knee at 64 nodes comes from the master serially processing gradient
//! messages, §3.5).  They are written as straight slices-of-f32 loops that
//! LLVM auto-vectorizes; `benches/micro.rs` tracks ns/param.

/// y += a * x  (the gradient-merge kernel).
#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * *xi;
    }
}

/// y += x.
#[inline]
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += *xi;
    }
}

/// x *= a.
#[inline]
pub fn scale(x: &mut [f32], a: f32) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

/// Dot product (f64 accumulator for stability in norms over ~100k params).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| *x as f64 * *y as f64)
        .sum()
}

/// ‖x‖₂.
#[inline]
pub fn l2_norm(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// Accumulates *sum* gradients from workers along with their example
/// counts, producing the weighted average the paper's reduce step uses
/// (§3.6: "a weighted average of gradients from all workers").
///
/// Workers return Σ-gradients over `n_k` examples; the weighted average is
/// (Σ_k g_k) / (Σ_k n_k) — heterogeneous batch counts are weighted
/// correctly for free.  The buffer is reused across iterations (zero
/// allocation on the hot path).
#[derive(Debug, Clone)]
pub struct GradAccumulator {
    sum: Vec<f32>,
    count: u64,
    contributions: u32,
}

impl GradAccumulator {
    pub fn new(dim: usize) -> Self {
        Self {
            sum: vec![0.0; dim],
            count: 0,
            contributions: 0,
        }
    }

    pub fn dim(&self) -> usize {
        self.sum.len()
    }

    /// Merge one worker's sum-gradient over `examples` data vectors.
    pub fn add(&mut self, grad_sum: &[f32], examples: u64) {
        assert_eq!(grad_sum.len(), self.sum.len(), "gradient dim mismatch");
        add_assign(&mut self.sum, grad_sum);
        self.count += examples;
        self.contributions += 1;
    }

    /// Merge a *sparse* partial gradient (index, value) pairs — the paper's
    /// §5 "partial communication of gradients" mitigation.  Values are sums
    /// over the worker's examples, same convention as `add`.
    pub fn add_sparse(&mut self, entries: &[(u32, f32)], examples: u64) {
        for &(i, v) in entries {
            self.sum[i as usize] += v;
        }
        self.count += examples;
        self.contributions += 1;
    }

    pub fn examples(&self) -> u64 {
        self.count
    }

    pub fn contributions(&self) -> u32 {
        self.contributions
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// The weighted-average gradient; empty accumulator yields zeros.
    pub fn weighted_average(&self) -> Vec<f32> {
        let mut avg = self.sum.clone();
        if self.count > 0 {
            scale(&mut avg, 1.0 / self.count as f32);
        }
        avg
    }

    /// In-place variant writing into a caller-provided buffer (hot path).
    pub fn weighted_average_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.sum.len());
        let inv = if self.count > 0 {
            1.0 / self.count as f32
        } else {
            0.0
        };
        for (o, s) in out.iter_mut().zip(self.sum.iter()) {
            *o = *s * inv;
        }
    }

    /// Reset for the next iteration without freeing the buffer.
    pub fn reset(&mut self) {
        self.sum.iter_mut().for_each(|x| *x = 0.0);
        self.count = 0;
        self.contributions = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let mut y = vec![1.0, 2.0];
        axpy(&mut y, 2.0, &[10.0, 20.0]);
        assert_eq!(y, vec![21.0, 42.0]);
    }

    #[test]
    fn dot_and_norm() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_average_respects_counts() {
        let mut acc = GradAccumulator::new(2);
        // worker A: 1 example with grad [1, 0]; worker B: 3 examples, sum [0, 6]
        acc.add(&[1.0, 0.0], 1);
        acc.add(&[0.0, 6.0], 3);
        assert_eq!(acc.weighted_average(), vec![0.25, 1.5]);
        assert_eq!(acc.examples(), 4);
        assert_eq!(acc.contributions(), 2);
    }

    #[test]
    fn empty_average_is_zero() {
        let acc = GradAccumulator::new(3);
        assert!(acc.is_empty());
        assert_eq!(acc.weighted_average(), vec![0.0; 3]);
    }

    #[test]
    fn sparse_equals_dense_on_support() {
        let mut dense = GradAccumulator::new(4);
        dense.add(&[0.0, 5.0, 0.0, -1.0], 2);
        let mut sparse = GradAccumulator::new(4);
        sparse.add_sparse(&[(1, 5.0), (3, -1.0)], 2);
        assert_eq!(dense.weighted_average(), sparse.weighted_average());
    }

    #[test]
    fn reset_reuses_buffer() {
        let mut acc = GradAccumulator::new(2);
        acc.add(&[1.0, 1.0], 1);
        acc.reset();
        assert!(acc.is_empty());
        assert_eq!(acc.weighted_average(), vec![0.0, 0.0]);
    }

    #[test]
    fn into_variant_matches() {
        let mut acc = GradAccumulator::new(3);
        acc.add(&[3.0, 6.0, 9.0], 3);
        let mut out = vec![0.0; 3];
        acc.weighted_average_into(&mut out);
        assert_eq!(out, acc.weighted_average());
    }

    #[test]
    #[should_panic(expected = "dim mismatch")]
    fn dim_mismatch_panics() {
        let mut acc = GradAccumulator::new(2);
        acc.add(&[1.0], 1);
    }
}
