//! Robust aggregation modes: surviving hostile gradients at the reduce.
//!
//! The paper's master computes a weighted average of worker gradients
//! (§3.6) — one adversarial submission steers the shared parameters
//! arbitrarily.  [`AggregationMode`] adds the standard byzantine-tolerant
//! estimators over the *same* shard arena as the mean reduce:
//!
//! * **`Mean`** — the paper baseline.  At the master this stays on the
//!   untouched `ShardedAccumulator::merge` path (bitwise-pinned since
//!   PR 5); the combiner here implements the equivalent weighted mean
//!   only so the serial-vs-sharded property tests can cover one shape.
//! * **`TrimmedMean(k)`** — per coordinate, drop the `k` smallest and
//!   `k` largest worker values and average the rest (unweighted over
//!   contributors; tolerant to `k` arbitrary outliers per side).  `k` is
//!   clamped to `(W − 1) / 2` so at least one value always survives.
//! * **`CoordinateMedian`** — per coordinate, the median worker value
//!   (even counts average the two middle values).
//! * **`ClipByNorm(c)`** — each worker's mean gradient is scaled down to
//!   L2 norm ≤ `c`, then example-weight averaged: bounds any single
//!   worker's pull without discarding honest mass.
//!
//! **Determinism.**  Per-coordinate combination reads worker values in
//! batch order, sorts them with `total_cmp`, and reduces in sorted order
//! — a fixed f32 operation sequence per coordinate, independent of the
//! shard that computes it.  `ShardedAccumulator::robust_aggregate_into`
//! is therefore bitwise-identical to the serial reference for any shard
//! count, pinned by `tests/prop_reduce.rs` alongside the mean reduce.

use super::sharded::GradView;

/// How one iteration's worker gradients combine into the step gradient.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AggregationMode {
    /// Example-weighted mean (the paper's reduce; no robustness).
    Mean,
    /// Per-coordinate trimmed mean dropping `k` values per side.
    TrimmedMean { k: usize },
    /// Per-coordinate median.
    CoordinateMedian,
    /// Per-worker L2 clip to `max_norm`, then weighted mean.
    ClipByNorm { max_norm: f32 },
}

impl AggregationMode {
    pub fn parse(s: &str) -> Result<Self, String> {
        if s == "mean" {
            Ok(AggregationMode::Mean)
        } else if s == "median" {
            Ok(AggregationMode::CoordinateMedian)
        } else if let Some(k) = s.strip_prefix("trimmed:") {
            let k: usize = k.parse().map_err(|_| format!("bad trim count '{k}'"))?;
            Ok(AggregationMode::TrimmedMean { k })
        } else if let Some(c) = s.strip_prefix("clip:") {
            let c: f32 = c.parse().map_err(|_| format!("bad clip norm '{c}'"))?;
            if !(c.is_finite() && c > 0.0) {
                return Err(format!("clip norm {c} must be finite and positive"));
            }
            Ok(AggregationMode::ClipByNorm { max_norm: c })
        } else {
            Err(format!(
                "unknown aggregation '{s}' (mean|trimmed:<k>|median|clip:<c>)"
            ))
        }
    }

    pub fn name(&self) -> String {
        match self {
            AggregationMode::Mean => "mean".into(),
            AggregationMode::TrimmedMean { k } => format!("trimmed:{k}"),
            AggregationMode::CoordinateMedian => "median".into(),
            AggregationMode::ClipByNorm { max_norm } => format!("clip:{max_norm}"),
        }
    }

    /// True for the modes that need the per-row combiner (everything but
    /// the accumulator-path mean).
    pub fn is_robust(&self) -> bool {
        !matches!(self, AggregationMode::Mean)
    }
}

/// One iteration's robust combiner: per-row state that must be computed
/// over *full* rows before per-shard combination can start (the clip
/// factors — a row's L2 norm spans every shard).  Rows with zero
/// examples carry no mean gradient and are skipped everywhere.
pub struct RobustCombiner {
    mode: AggregationMode,
    /// For `ClipByNorm`: `(per-valid-row weight, Σ example weights)`,
    /// aligned with the valid-row order `combine_range` walks.
    clip: Option<(Vec<f32>, f32)>,
}

impl RobustCombiner {
    /// Build the combiner; for `ClipByNorm` this walks every row once
    /// serially (row norms are global across shards, so they cannot be
    /// computed inside the per-shard pass).
    pub fn new(mode: AggregationMode, batch: &[(GradView<'_>, u64)]) -> Self {
        let clip = match mode {
            AggregationMode::ClipByNorm { max_norm } => {
                let mut factors = Vec::new();
                let mut denom = 0.0f32;
                for &(view, examples) in batch {
                    if examples == 0 {
                        continue;
                    }
                    let inv = 1.0 / examples as f32;
                    // Σ mean² in coordinate order — sparse rows only
                    // carry their stored coordinates (zeros add nothing).
                    let norm_sq: f32 = match view {
                        GradView::Dense(g) => {
                            g.iter().map(|&v| (v * inv) * (v * inv)).sum()
                        }
                        GradView::Sparse(entries) => {
                            entries.iter().map(|&(_, v)| (v * inv) * (v * inv)).sum()
                        }
                    };
                    let norm = norm_sq.sqrt();
                    let scale = if norm > max_norm { max_norm / norm } else { 1.0 };
                    factors.push(examples as f32 * scale);
                    denom += examples as f32;
                }
                Some((factors, denom))
            }
            _ => None,
        };
        RobustCombiner { mode, clip }
    }

    /// Combine the batch over parameter range `[lo, lo + out.len())`,
    /// writing the step gradient into `out`.  Safe to call concurrently
    /// for disjoint ranges (`&self`; no interior mutability).
    pub fn combine_range(&self, batch: &[(GradView<'_>, u64)], lo: usize, out: &mut [f32]) {
        let cols = out.len();
        if cols == 0 {
            return;
        }
        // Materialize each valid row's mean gradient over this range —
        // rows × cols dense matrix, filled in batch order.
        let mut rows: Vec<f32> = Vec::new();
        let mut n_rows = 0usize;
        for &(view, examples) in batch {
            if examples == 0 {
                continue;
            }
            let inv = 1.0 / examples as f32;
            let base = rows.len();
            rows.resize(base + cols, 0.0);
            let row = &mut rows[base..];
            match view {
                GradView::Dense(g) => {
                    for (j, slot) in row.iter_mut().enumerate() {
                        *slot = g[lo + j] * inv;
                    }
                }
                GradView::Sparse(entries) => {
                    let hi = lo + cols;
                    let a = entries.partition_point(|&(i, _)| (i as usize) < lo);
                    let b = entries.partition_point(|&(i, _)| (i as usize) < hi);
                    for &(i, v) in &entries[a..b] {
                        row[i as usize - lo] = v * inv;
                    }
                }
            }
            n_rows += 1;
        }
        if n_rows == 0 {
            out.fill(0.0);
            return;
        }

        let mut col: Vec<f32> = Vec::with_capacity(n_rows);
        for (j, slot) in out.iter_mut().enumerate() {
            col.clear();
            col.extend((0..n_rows).map(|r| rows[r * cols + j]));
            *slot = match self.mode {
                AggregationMode::Mean => {
                    // Weighted mean over valid rows (test reference only;
                    // the master's Mean path is the accumulator).
                    let mut num = 0.0f32;
                    let mut den = 0.0f32;
                    let mut r = 0;
                    for &(_, examples) in batch {
                        if examples == 0 {
                            continue;
                        }
                        num += col[r] * examples as f32;
                        den += examples as f32;
                        r += 1;
                    }
                    num / den
                }
                AggregationMode::TrimmedMean { k } => {
                    col.sort_unstable_by(f32::total_cmp);
                    let k_eff = k.min((n_rows - 1) / 2);
                    let kept = &col[k_eff..n_rows - k_eff];
                    kept.iter().sum::<f32>() / kept.len() as f32
                }
                AggregationMode::CoordinateMedian => {
                    col.sort_unstable_by(f32::total_cmp);
                    let mid = n_rows / 2;
                    if n_rows % 2 == 1 {
                        col[mid]
                    } else {
                        0.5 * (col[mid - 1] + col[mid])
                    }
                }
                AggregationMode::ClipByNorm { .. } => {
                    let (factors, denom) =
                        self.clip.as_ref().expect("clip weights precomputed");
                    let mut num = 0.0f32;
                    for (r, &w) in factors.iter().enumerate() {
                        num += col[r] * w;
                    }
                    num / denom
                }
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn combine(mode: AggregationMode, batch: &[(GradView<'_>, u64)], dim: usize) -> Vec<f32> {
        let mut out = vec![0.0; dim];
        RobustCombiner::new(mode, batch).combine_range(batch, 0, &mut out);
        out
    }

    #[test]
    fn parse_round_trips() {
        for s in ["mean", "trimmed:3", "median", "clip:5"] {
            assert_eq!(AggregationMode::parse(s).unwrap().name(), s);
        }
        assert!(AggregationMode::parse("clip:0").is_err());
        assert!(AggregationMode::parse("clip:-1").is_err());
        assert!(AggregationMode::parse("trimmed:x").is_err());
        assert!(AggregationMode::parse("wat").is_err());
    }

    #[test]
    fn trimmed_mean_discards_outliers() {
        // Five workers, one hostile (×100): trim 1 per side recovers the
        // honest value exactly (honest rows are identical).
        let honest = vec![1.0f32, -2.0];
        let hostile = vec![100.0f32, -200.0];
        let batch: Vec<(GradView<'_>, u64)> = vec![
            (GradView::Dense(&honest), 1),
            (GradView::Dense(&honest), 1),
            (GradView::Dense(&hostile), 1),
            (GradView::Dense(&honest), 1),
            (GradView::Dense(&honest), 1),
        ];
        let out = combine(AggregationMode::TrimmedMean { k: 1 }, &batch, 2);
        assert_eq!(out, vec![1.0, -2.0]);
    }

    #[test]
    fn trim_clamps_so_a_value_survives() {
        let g = vec![3.0f32];
        let batch: Vec<(GradView<'_>, u64)> = vec![(GradView::Dense(&g), 1)];
        // k=5 over one row: k_eff = 0, result is the row itself.
        assert_eq!(combine(AggregationMode::TrimmedMean { k: 5 }, &batch, 1), vec![3.0]);
    }

    #[test]
    fn median_odd_and_even() {
        let rows = [vec![1.0f32], vec![5.0f32], vec![9.0f32], vec![100.0f32]];
        let odd: Vec<(GradView<'_>, u64)> =
            rows[..3].iter().map(|r| (GradView::Dense(r.as_slice()), 1)).collect();
        assert_eq!(combine(AggregationMode::CoordinateMedian, &odd, 1), vec![5.0]);
        let even: Vec<(GradView<'_>, u64)> =
            rows.iter().map(|r| (GradView::Dense(r.as_slice()), 1)).collect();
        assert_eq!(combine(AggregationMode::CoordinateMedian, &even, 1), vec![7.0]);
    }

    #[test]
    fn clip_bounds_a_hostile_worker_and_passes_honest_mass() {
        // Honest row has norm 1 (< c): untouched.  Hostile row norm 100:
        // scaled to norm 2.  Weighted mean with equal examples.
        let honest = vec![1.0f32, 0.0];
        let hostile = vec![100.0f32, 0.0];
        let batch: Vec<(GradView<'_>, u64)> =
            vec![(GradView::Dense(&honest), 1), (GradView::Dense(&hostile), 1)];
        let out = combine(AggregationMode::ClipByNorm { max_norm: 2.0 }, &batch, 2);
        assert_eq!(out, vec![(1.0 + 2.0) / 2.0, 0.0]);
    }

    #[test]
    fn clip_without_outliers_equals_weighted_mean() {
        let a = vec![0.5f32, -0.25];
        let b = vec![0.1f32, 0.3];
        let batch: Vec<(GradView<'_>, u64)> =
            vec![(GradView::Dense(&a), 3), (GradView::Dense(&b), 1)];
        let clipped = combine(AggregationMode::ClipByNorm { max_norm: 1e6 }, &batch, 2);
        let mean = combine(AggregationMode::Mean, &batch, 2);
        assert_eq!(clipped, mean);
    }

    #[test]
    fn sparse_rows_contribute_zeros_off_support() {
        let dense = vec![4.0f32, 4.0, 4.0];
        let sparse: Vec<(u32, f32)> = vec![(1, 8.0)];
        let batch: Vec<(GradView<'_>, u64)> = vec![
            (GradView::Dense(&dense), 2),
            (GradView::Sparse(&sparse), 2),
            (GradView::Dense(&dense), 2),
        ];
        // Medians per coordinate: [2, 2, 2] vs sparse row [0, 4, 0].
        assert_eq!(combine(AggregationMode::CoordinateMedian, &batch, 3), vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn zero_example_rows_are_skipped() {
        let g = vec![1.0f32];
        let junk = vec![999.0f32];
        let batch: Vec<(GradView<'_>, u64)> =
            vec![(GradView::Dense(&g), 2), (GradView::Dense(&junk), 0)];
        assert_eq!(combine(AggregationMode::CoordinateMedian, &batch, 1), vec![0.5]);
        let empty: Vec<(GradView<'_>, u64)> = vec![(GradView::Dense(&junk), 0)];
        assert_eq!(combine(AggregationMode::CoordinateMedian, &empty, 1), vec![0.0]);
    }

    #[test]
    fn range_combination_is_independent_of_split() {
        // Combining [0,5) in one call equals combining [0,2)+[2,5).
        let a: Vec<f32> = (0..5).map(|i| i as f32 * 0.3 - 1.0).collect();
        let b: Vec<f32> = (0..5).map(|i| (i as f32).cos()).collect();
        let c: Vec<f32> = (0..5).map(|i| -(i as f32) * 0.7).collect();
        let batch: Vec<(GradView<'_>, u64)> = vec![
            (GradView::Dense(&a), 2),
            (GradView::Dense(&b), 3),
            (GradView::Dense(&c), 1),
        ];
        for mode in [
            AggregationMode::TrimmedMean { k: 1 },
            AggregationMode::CoordinateMedian,
            AggregationMode::ClipByNorm { max_norm: 0.5 },
        ] {
            let combiner = RobustCombiner::new(mode, &batch);
            let mut whole = vec![0.0; 5];
            combiner.combine_range(&batch, 0, &mut whole);
            let mut split = vec![0.0; 5];
            let (head, tail) = split.split_at_mut(2);
            combiner.combine_range(&batch, 0, head);
            combiner.combine_range(&batch, 2, tail);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&whole), bits(&split), "{}", mode.name());
        }
    }
}
