//! Parameter-sharded, multi-threaded gradient reduce.
//!
//! The paper's scaling knee at 64 nodes is the master serially merging
//! gradient messages (§3.5); its proposed mitigation — multiple reduce
//! processes — existed here only as a *modeled* parameter
//! (`netsim::MasterModel`).  [`ShardedAccumulator`] makes the reduce
//! actually parallel: the flat parameter vector is partitioned into `S`
//! contiguous shards with fixed boundaries, and one iteration's worth of
//! submissions is merged with each shard's slice on its own thread
//! (scoped threads over the persistent shard arena — the `sum` buffer is
//! reused across iterations, so the hot path allocates nothing per
//! gradient).
//!
//! **Determinism.**  Results are bitwise-identical to the single-threaded
//! [`GradAccumulator`](super::GradAccumulator) given the same submission
//! order: every kernel is elementwise, shard boundaries are fixed, and
//! each shard applies submissions in batch order — so each parameter
//! element sees exactly the same f32 additions in exactly the same order,
//! just on a different thread.  `tests/prop_reduce.rs` pins this for
//! S ∈ {1, 2, 4, 7}, including non-dividing shard counts.
//!
//! Sparse (partial-gradient) payloads arrive sorted by index; each shard
//! binary-searches the entry list against its boundary (`partition_point`)
//! and merges only its sub-range.

use super::vecmath::{add_assign, scaled_copy};

/// A borrowed view of one submission's gradient for the reduce step.
///
/// Dense payloads are full Σ-gradients; sparse payloads are (index,
/// Σ-value) pairs **sorted by index** (what `Payload::sparsify` emits) —
/// sortedness is what lets shards binary-search their sub-range.
#[derive(Debug, Clone, Copy)]
pub enum GradView<'a> {
    Dense(&'a [f32]),
    Sparse(&'a [(u32, f32)]),
}

/// Parameter-sharded accumulator: the production reduce path.
#[derive(Debug, Clone)]
pub struct ShardedAccumulator {
    /// The shard arena: one flat buffer, threads write disjoint slices.
    sum: Vec<f32>,
    /// `shards + 1` ascending split points; shard `s` owns
    /// `sum[bounds[s]..bounds[s + 1]]`.
    bounds: Vec<usize>,
    count: u64,
    contributions: u32,
}

impl ShardedAccumulator {
    /// `shards` is clamped to `[1, max(dim, 1)]` — more shards than
    /// parameters would only spawn idle threads.
    pub fn new(dim: usize, shards: usize) -> Self {
        let s = shards.clamp(1, dim.max(1));
        // Even partition; the first `dim % s` shards take one extra
        // element, so boundaries are fixed functions of (dim, s).
        let (base, rem) = (dim / s, dim % s);
        let bounds: Vec<usize> = (0..=s).map(|k| k * base + k.min(rem)).collect();
        Self {
            sum: vec![0.0; dim],
            bounds,
            count: 0,
            contributions: 0,
        }
    }

    pub fn dim(&self) -> usize {
        self.sum.len()
    }

    pub fn n_shards(&self) -> usize {
        self.bounds.len() - 1
    }

    /// The fixed split points (`n_shards() + 1` ascending values).
    pub fn shard_bounds(&self) -> &[usize] {
        &self.bounds
    }

    pub fn examples(&self) -> u64 {
        self.count
    }

    pub fn contributions(&self) -> u32 {
        self.contributions
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Merge one iteration's submissions (gradient view + example count
    /// each), sharded across threads.
    ///
    /// All payloads are validated *before* any merge work starts (dense
    /// dimension, sparse index bounds and sortedness), so a corrupt
    /// message panics descriptively with the accumulator untouched.
    pub fn merge(&mut self, batch: &[(GradView<'_>, u64)]) {
        let dim = self.sum.len();
        validate_batch(dim, batch);
        for &(_, examples) in batch {
            self.count += examples;
            self.contributions += 1;
        }
        if batch.is_empty() || dim == 0 {
            return;
        }

        if self.n_shards() == 1 {
            merge_shard(&mut self.sum, 0, batch);
            return;
        }

        // Split the arena at the fixed boundaries and merge each shard's
        // slice on its own thread; shard 0 runs on the calling thread so
        // S shards cost S − 1 spawns.
        let mut slices: Vec<(usize, &mut [f32])> = Vec::with_capacity(self.n_shards());
        let mut rest: &mut [f32] = &mut self.sum;
        let mut start = 0;
        for w in self.bounds.windows(2) {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(w[1] - w[0]);
            slices.push((start, head));
            rest = tail;
            start = w[1];
        }
        std::thread::scope(|scope| {
            let mut it = slices.into_iter();
            let first = it.next().expect("at least one shard");
            for (lo, slice) in it {
                scope.spawn(move || merge_shard(slice, lo, batch));
            }
            merge_shard(first.1, first.0, batch);
        });
    }

    /// The weighted-average gradient; empty accumulator yields zeros.
    pub fn weighted_average(&self) -> Vec<f32> {
        let mut avg = vec![0.0; self.sum.len()];
        self.weighted_average_into(&mut avg);
        avg
    }

    /// In-place variant writing into a caller-provided buffer (hot path —
    /// the master reuses one scratch buffer across iterations).
    pub fn weighted_average_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.sum.len());
        let inv = if self.count > 0 {
            1.0 / self.count as f32
        } else {
            0.0
        };
        scaled_copy(out, inv, &self.sum);
    }

    /// Reset for the next iteration without freeing the arena.
    pub fn reset(&mut self) {
        self.sum.fill(0.0);
        self.count = 0;
        self.contributions = 0;
    }

    /// Robust aggregation over the same shard layout as [`merge`]
    /// (`params::AggregationMode` — trimmed mean / coordinate median /
    /// clip-by-norm): each shard combines its parameter range on its own
    /// thread, writing the step gradient straight into `out`.  Unlike
    /// `merge` this reads per-row views directly (robust estimators need
    /// every worker's value per coordinate, not just the running sum), so
    /// the arena's `sum`/`count` state is untouched.
    ///
    /// Bitwise-identical to the serial `RobustCombiner` reference for any
    /// shard count — per-coordinate work is independent of the shard that
    /// runs it (pinned in `tests/prop_reduce.rs`).
    ///
    /// [`merge`]: Self::merge
    pub fn robust_aggregate_into(
        &self,
        mode: super::AggregationMode,
        batch: &[(GradView<'_>, u64)],
        out: &mut [f32],
    ) {
        let dim = self.sum.len();
        assert_eq!(out.len(), dim, "output dim mismatch");
        validate_batch(dim, batch);
        if dim == 0 {
            return;
        }
        let combiner = super::RobustCombiner::new(mode, batch);
        if self.n_shards() == 1 {
            combiner.combine_range(batch, 0, out);
            return;
        }
        let mut slices: Vec<(usize, &mut [f32])> = Vec::with_capacity(self.n_shards());
        let mut rest: &mut [f32] = out;
        let mut start = 0;
        for w in self.bounds.windows(2) {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(w[1] - w[0]);
            slices.push((start, head));
            rest = tail;
            start = w[1];
        }
        let combiner = &combiner;
        std::thread::scope(|scope| {
            let mut it = slices.into_iter();
            let first = it.next().expect("at least one shard");
            for (lo, slice) in it {
                scope.spawn(move || combiner.combine_range(batch, lo, slice));
            }
            combiner.combine_range(batch, first.0, first.1);
        });
    }
}

/// Shared payload validation: all submissions are checked *before* any
/// merge or combine work starts (dense dimension, sparse index bounds and
/// sortedness), so a corrupt message panics descriptively with the
/// accumulator untouched.
fn validate_batch(dim: usize, batch: &[(GradView<'_>, u64)]) {
    for (view, _) in batch {
        match view {
            GradView::Dense(g) => {
                assert_eq!(g.len(), dim, "gradient dim mismatch");
            }
            GradView::Sparse(entries) => {
                let mut prev: Option<u32> = None;
                for &(i, _) in *entries {
                    if i as usize >= dim {
                        panic!("sparse gradient index {i} out of bounds for dim {dim}");
                    }
                    if let Some(p) = prev {
                        if i <= p {
                            panic!(
                                "sparse gradient entries not sorted by index \
                                 ({i} after {p})"
                            );
                        }
                    }
                    prev = Some(i);
                }
            }
        }
    }
}

/// Merge every submission's `[lo, lo + slice.len())` range into one
/// shard's slice, in batch order (the determinism contract).
fn merge_shard(slice: &mut [f32], lo: usize, batch: &[(GradView<'_>, u64)]) {
    let hi = lo + slice.len();
    for (view, _) in batch {
        match view {
            GradView::Dense(g) => add_assign(slice, &g[lo..hi]),
            GradView::Sparse(entries) => {
                let a = entries.partition_point(|&(i, _)| (i as usize) < lo);
                let b = entries.partition_point(|&(i, _)| (i as usize) < hi);
                for &(i, v) in &entries[a..b] {
                    slice[i as usize - lo] += v;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::GradAccumulator;

    #[test]
    fn bounds_partition_evenly_with_remainder_up_front() {
        let acc = ShardedAccumulator::new(10, 4);
        assert_eq!(acc.shard_bounds(), &[0, 3, 6, 8, 10]);
        assert_eq!(acc.n_shards(), 4);
        let acc = ShardedAccumulator::new(8, 4);
        assert_eq!(acc.shard_bounds(), &[0, 2, 4, 6, 8]);
    }

    #[test]
    fn shard_count_clamps_to_dim() {
        assert_eq!(ShardedAccumulator::new(3, 16).n_shards(), 3);
        assert_eq!(ShardedAccumulator::new(0, 4).n_shards(), 1);
        assert_eq!(ShardedAccumulator::new(5, 0).n_shards(), 1);
    }

    #[test]
    fn matches_reference_accumulator_dense_and_sparse() {
        let g1: Vec<f32> = (0..10).map(|i| i as f32 * 0.25 - 1.0).collect();
        let g2: Vec<f32> = (0..10).map(|i| (i as f32).sin()).collect();
        let sparse: Vec<(u32, f32)> = vec![(0, 1.5), (4, -2.0), (9, 0.125)];
        let mut reference = GradAccumulator::new(10);
        reference.add(&g1, 2);
        reference.add_sparse(&sparse, 1);
        reference.add(&g2, 3);
        for shards in [1, 2, 4, 7] {
            let mut acc = ShardedAccumulator::new(10, shards);
            acc.merge(&[
                (GradView::Dense(&g1), 2),
                (GradView::Sparse(&sparse), 1),
                (GradView::Dense(&g2), 3),
            ]);
            assert_eq!(
                acc.weighted_average(),
                reference.weighted_average(),
                "shards={shards}"
            );
            assert_eq!(acc.examples(), 6);
            assert_eq!(acc.contributions(), 3);
        }
    }

    #[test]
    fn incremental_merges_accumulate() {
        let mut acc = ShardedAccumulator::new(4, 2);
        acc.merge(&[(GradView::Dense(&[1.0, 0.0, 0.0, 0.0]), 1)]);
        acc.merge(&[(GradView::Dense(&[0.0, 6.0, 0.0, 0.0]), 3)]);
        assert_eq!(acc.weighted_average(), vec![0.25, 1.5, 0.0, 0.0]);
    }

    #[test]
    fn empty_batch_and_empty_accumulator() {
        let mut acc = ShardedAccumulator::new(5, 2);
        acc.merge(&[]);
        assert!(acc.is_empty());
        assert_eq!(acc.weighted_average(), vec![0.0; 5]);
    }

    #[test]
    fn reset_reuses_arena() {
        let mut acc = ShardedAccumulator::new(4, 2);
        acc.merge(&[(GradView::Dense(&[1.0; 4]), 1)]);
        acc.reset();
        assert!(acc.is_empty());
        assert_eq!(acc.weighted_average(), vec![0.0; 4]);
        assert_eq!(acc.n_shards(), 2, "reset keeps the shard layout");
    }

    #[test]
    #[should_panic(expected = "sparse gradient index 8 out of bounds for dim 4")]
    fn corrupt_sparse_index_panics_before_merge() {
        let mut acc = ShardedAccumulator::new(4, 2);
        acc.merge(&[(GradView::Sparse(&[(0, 1.0), (8, 1.0)]), 1)]);
    }

    #[test]
    #[should_panic(expected = "not sorted by index")]
    fn unsorted_sparse_entries_panic() {
        let mut acc = ShardedAccumulator::new(4, 2);
        acc.merge(&[(GradView::Sparse(&[(2, 1.0), (1, 1.0)]), 1)]);
    }

    #[test]
    fn validation_happens_before_any_state_change() {
        let mut acc = ShardedAccumulator::new(4, 2);
        acc.merge(&[(GradView::Dense(&[1.0; 4]), 2)]);
        let before = acc.weighted_average();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            acc.merge(&[
                (GradView::Dense(&[2.0; 4]), 1),
                (GradView::Sparse(&[(100, 1.0)]), 1),
            ]);
        }));
        assert!(res.is_err());
        assert_eq!(acc.weighted_average(), before);
        assert_eq!(acc.examples(), 2);
        assert_eq!(acc.contributions(), 1);
    }
}
