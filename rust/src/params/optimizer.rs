//! Optimizers applied by the master after the reduce step.
//!
//! The paper's prototype uses **AdaGrad** (§3.6, [31] Duchi et al.); plain
//! SGD, momentum and RMSProp are included as baselines for the convergence
//! ablations.  All operate in place on the flat parameter vector with
//! per-coordinate state owned by the optimizer (master-side, never
//! communicated — only parameters are broadcast).

/// A gradient-step rule over flat parameter vectors.
pub trait Optimizer: Send {
    /// Apply one update given the weighted-average gradient.
    fn step(&mut self, params: &mut [f32], grad: &[f32]);
    /// Learning rate accessor (UI-adjustable in the paper's client, §3.6).
    fn learning_rate(&self) -> f32;
    fn set_learning_rate(&mut self, lr: f32);
    /// Name for closures/metrics.
    fn name(&self) -> &'static str;
    /// Per-coordinate state vector for checkpointing (empty for
    /// stateless rules). A copy, in a fixed layout per optimizer —
    /// AdaGrad/RMSProp squared-gradient history, momentum velocity.
    fn state(&self) -> Vec<f32> {
        Vec::new()
    }
    /// Restore state captured by [`state`](Self::state). Panics on a
    /// length mismatch: a checkpoint from a different model/optimizer
    /// must never be silently accepted.
    fn restore_state(&mut self, state: &[f32]) {
        assert!(
            state.is_empty(),
            "{}: stateless optimizer given {} state values",
            self.name(),
            state.len()
        );
    }
}

/// Which optimizer to build (parsed from CLI / research closures).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OptimizerKind {
    Sgd,
    Momentum,
    AdaGrad,
    RmsProp,
}

impl OptimizerKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "sgd" => Ok(Self::Sgd),
            "momentum" => Ok(Self::Momentum),
            "adagrad" => Ok(Self::AdaGrad),
            "rmsprop" => Ok(Self::RmsProp),
            _ => Err(format!("unknown optimizer '{s}' (sgd|momentum|adagrad|rmsprop)")),
        }
    }

    /// Instantiate with standard hyper-parameters.
    pub fn build(self, dim: usize, lr: f32) -> Box<dyn Optimizer> {
        match self {
            Self::Sgd => Box::new(Sgd::new(lr)),
            Self::Momentum => Box::new(Momentum::new(dim, lr, 0.9)),
            Self::AdaGrad => Box::new(AdaGrad::new(dim, lr, 1e-8)),
            Self::RmsProp => Box::new(RmsProp::new(dim, lr, 0.99, 1e-8)),
        }
    }
}

/// Plain SGD: p ← p − lr·g.
#[derive(Debug, Clone)]
pub struct Sgd {
    lr: f32,
}

impl Sgd {
    pub fn new(lr: f32) -> Self {
        Self { lr }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        debug_assert_eq!(params.len(), grad.len());
        let lr = self.lr;
        for (p, g) in params.iter_mut().zip(grad.iter()) {
            *p -= lr * *g;
        }
    }
    fn learning_rate(&self) -> f32 {
        self.lr
    }
    fn set_learning_rate(&mut self, lr: f32) {
        self.lr = lr;
    }
    fn name(&self) -> &'static str {
        "sgd"
    }
}

/// Classical momentum: v ← μv + g; p ← p − lr·v.
#[derive(Debug, Clone)]
pub struct Momentum {
    lr: f32,
    mu: f32,
    velocity: Vec<f32>,
}

impl Momentum {
    pub fn new(dim: usize, lr: f32, mu: f32) -> Self {
        Self {
            lr,
            mu,
            velocity: vec![0.0; dim],
        }
    }
}

impl Optimizer for Momentum {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        debug_assert_eq!(params.len(), grad.len());
        debug_assert_eq!(params.len(), self.velocity.len());
        let (lr, mu) = (self.lr, self.mu);
        for ((p, g), v) in params.iter_mut().zip(grad.iter()).zip(self.velocity.iter_mut()) {
            *v = mu * *v + *g;
            *p -= lr * *v;
        }
    }
    fn learning_rate(&self) -> f32 {
        self.lr
    }
    fn set_learning_rate(&mut self, lr: f32) {
        self.lr = lr;
    }
    fn name(&self) -> &'static str {
        "momentum"
    }
    fn state(&self) -> Vec<f32> {
        self.velocity.clone()
    }
    fn restore_state(&mut self, state: &[f32]) {
        assert_eq!(
            state.len(),
            self.velocity.len(),
            "momentum: state length mismatch"
        );
        self.velocity.copy_from_slice(state);
    }
}

/// AdaGrad (Duchi et al. 2011) — the paper's update rule:
/// h ← h + g²; p ← p − lr·g / (√h + ε).
#[derive(Debug, Clone)]
pub struct AdaGrad {
    lr: f32,
    eps: f32,
    hist: Vec<f32>,
}

impl AdaGrad {
    pub fn new(dim: usize, lr: f32, eps: f32) -> Self {
        Self {
            lr,
            eps,
            hist: vec![0.0; dim],
        }
    }

    /// Accumulated squared-gradient state (inspectable for tests/closures).
    pub fn history(&self) -> &[f32] {
        &self.hist
    }
}

impl Optimizer for AdaGrad {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        debug_assert_eq!(params.len(), grad.len());
        debug_assert_eq!(params.len(), self.hist.len());
        let (lr, eps) = (self.lr, self.eps);
        for ((p, g), h) in params.iter_mut().zip(grad.iter()).zip(self.hist.iter_mut()) {
            *h += *g * *g;
            *p -= lr * *g / (h.sqrt() + eps);
        }
    }
    fn learning_rate(&self) -> f32 {
        self.lr
    }
    fn set_learning_rate(&mut self, lr: f32) {
        self.lr = lr;
    }
    fn name(&self) -> &'static str {
        "adagrad"
    }
    fn state(&self) -> Vec<f32> {
        self.hist.clone()
    }
    fn restore_state(&mut self, state: &[f32]) {
        assert_eq!(state.len(), self.hist.len(), "adagrad: state length mismatch");
        self.hist.copy_from_slice(state);
    }
}

/// RMSProp: h ← ρh + (1−ρ)g²; p ← p − lr·g / (√h + ε).
#[derive(Debug, Clone)]
pub struct RmsProp {
    lr: f32,
    rho: f32,
    eps: f32,
    hist: Vec<f32>,
}

impl RmsProp {
    pub fn new(dim: usize, lr: f32, rho: f32, eps: f32) -> Self {
        Self {
            lr,
            rho,
            eps,
            hist: vec![0.0; dim],
        }
    }
}

impl Optimizer for RmsProp {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        debug_assert_eq!(params.len(), grad.len());
        let (lr, rho, eps) = (self.lr, self.rho, self.eps);
        for ((p, g), h) in params.iter_mut().zip(grad.iter()).zip(self.hist.iter_mut()) {
            *h = rho * *h + (1.0 - rho) * *g * *g;
            *p -= lr * *g / (h.sqrt() + eps);
        }
    }
    fn learning_rate(&self) -> f32 {
        self.lr
    }
    fn set_learning_rate(&mut self, lr: f32) {
        self.lr = lr;
    }
    fn name(&self) -> &'static str {
        "rmsprop"
    }
    fn state(&self) -> Vec<f32> {
        self.hist.clone()
    }
    fn restore_state(&mut self, state: &[f32]) {
        assert_eq!(state.len(), self.hist.len(), "rmsprop: state length mismatch");
        self.hist.copy_from_slice(state);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_step() {
        let mut opt = Sgd::new(0.5);
        let mut p = vec![1.0, -1.0];
        opt.step(&mut p, &[2.0, -2.0]);
        assert_eq!(p, vec![0.0, 0.0]);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut opt = Momentum::new(1, 0.1, 0.9);
        let mut p = vec![0.0];
        opt.step(&mut p, &[1.0]); // v=1,   p=-0.1
        opt.step(&mut p, &[1.0]); // v=1.9, p=-0.29
        assert!((p[0] + 0.29).abs() < 1e-6, "{p:?}");
    }

    #[test]
    fn adagrad_shrinks_effective_lr() {
        let mut opt = AdaGrad::new(1, 0.1, 0.0);
        let mut p = vec![0.0];
        opt.step(&mut p, &[1.0]);
        let d1 = -p[0]; // 0.1 / sqrt(1)
        let before = p[0];
        opt.step(&mut p, &[1.0]);
        let d2 = before - p[0]; // 0.1 / sqrt(2)
        assert!(d2 < d1, "d1={d1} d2={d2}");
        assert!((d2 - 0.1 / 2.0_f32.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn adagrad_invariant_to_gradient_scale_direction() {
        // AdaGrad's first step is lr * sign(g) (per coordinate, eps=0).
        let mut a = AdaGrad::new(2, 0.1, 0.0);
        let mut pa = vec![0.0, 0.0];
        a.step(&mut pa, &[100.0, -0.001]);
        assert!((pa[0] + 0.1).abs() < 1e-6);
        assert!((pa[1] - 0.1).abs() < 1e-6);
    }

    #[test]
    fn rmsprop_converges_on_quadratic() {
        // minimize f(p)=p² ; grad=2p
        let mut opt = RmsProp::new(1, 0.05, 0.9, 1e-8);
        let mut p = vec![5.0f32];
        for _ in 0..300 {
            let g = [2.0 * p[0]];
            opt.step(&mut p, &g);
        }
        assert!(p[0].abs() < 0.1, "{p:?}");
    }

    #[test]
    fn all_optimizers_reduce_quadratic_loss() {
        for kind in [
            OptimizerKind::Sgd,
            OptimizerKind::Momentum,
            OptimizerKind::AdaGrad,
            OptimizerKind::RmsProp,
        ] {
            let mut opt = kind.build(2, 0.05);
            let mut p = vec![3.0f32, -2.0];
            let f = |p: &[f32]| p[0] * p[0] + p[1] * p[1];
            let f0 = f(&p);
            for _ in 0..500 {
                let g = [2.0 * p[0], 2.0 * p[1]];
                opt.step(&mut p, &g);
            }
            // AdaGrad's effective lr decays as 1/√t, so it moves slowest;
            // all must still cut the quadratic loss by ≥2×.
            assert!(f(&p) < f0 * 0.5, "{} failed: {} -> {}", opt.name(), f0, f(&p));
        }
    }

    #[test]
    fn kind_parsing() {
        assert_eq!(OptimizerKind::parse("adagrad").unwrap(), OptimizerKind::AdaGrad);
        assert!(OptimizerKind::parse("adam").is_err());
    }

    #[test]
    fn state_roundtrip_resumes_bitwise() {
        // For every optimizer: run k steps, export state, rebuild fresh,
        // restore, and check the next steps are bit-identical to an
        // uninterrupted run — the invariant the durable-state plane pins
        // at full-simulation scale.
        for kind in [
            OptimizerKind::Sgd,
            OptimizerKind::Momentum,
            OptimizerKind::AdaGrad,
            OptimizerKind::RmsProp,
        ] {
            let mut live = kind.build(3, 0.05);
            let mut p_live = vec![1.0f32, -2.0, 0.5];
            let grads = [[0.3f32, -0.1, 0.9], [0.2, 0.4, -0.6], [-0.5, 0.1, 0.2]];
            for g in &grads {
                live.step(&mut p_live, g);
            }
            let saved_state = live.state();
            let saved_params = p_live.clone();

            let mut resumed = kind.build(3, 0.05);
            resumed.restore_state(&saved_state);
            let mut p_resumed = saved_params;
            for g in &grads {
                live.step(&mut p_live, g);
                resumed.step(&mut p_resumed, g);
            }
            let live_bits: Vec<u32> = p_live.iter().map(|v| v.to_bits()).collect();
            let res_bits: Vec<u32> = p_resumed.iter().map(|v| v.to_bits()).collect();
            assert_eq!(live_bits, res_bits, "{} diverged after restore", live.name());
        }
    }

    #[test]
    #[should_panic(expected = "state length mismatch")]
    fn restore_rejects_wrong_dimension() {
        let mut opt = OptimizerKind::AdaGrad.build(4, 0.1);
        opt.restore_state(&[1.0, 2.0]);
    }

    #[test]
    fn learning_rate_is_adjustable() {
        let mut opt = OptimizerKind::AdaGrad.build(1, 0.1);
        opt.set_learning_rate(0.5);
        assert_eq!(opt.learning_rate(), 0.5);
    }
}
