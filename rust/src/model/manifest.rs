//! `artifacts/manifest.json` reader — the contract between the AOT
//! compile path and the Rust runtime (shapes, parameter layout, artifact
//! filenames + checksums).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::json::{self, Value};

/// One parameter tensor inside the flat vector (mirror of the Python
/// `TensorSpec`).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub fan_in: usize,
}

/// One compiled model variant.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub param_count: usize,
    pub batch_size: usize,
    /// Microbatch sizes compiled for grad/eval, largest first (§3.3d:
    /// weak devices pick a smaller work quantum).
    pub micro_batches: Vec<usize>,
    /// Input tensor shape [H, W, C].
    pub input: Vec<usize>,
    pub classes: usize,
    pub tensors: Vec<TensorSpec>,
    /// kind ("grad"/"eval"/"predict", "grad_b8", ...) → artifact filename.
    pub artifacts: BTreeMap<String, String>,
}

impl ModelSpec {
    /// Pixels per example.
    pub fn input_len(&self) -> usize {
        self.input.iter().product()
    }

    /// Artifact key for a (kind, microbatch) pair: the default batch uses
    /// the bare kind, variants are suffixed (`grad_b8`).
    pub fn artifact_key(&self, kind: &str, batch: usize) -> String {
        if batch == self.batch_size {
            kind.to_string()
        } else {
            format!("{kind}_b{batch}")
        }
    }

    /// Largest compiled microbatch whose compute time fits `budget_ms` at
    /// `power_vps` vectors/sec (falls back to the smallest quantum — the
    /// paper's mobiles compute "only a few gradients per second").
    pub fn pick_micro_batch(&self, power_vps: f64, budget_ms: f64) -> usize {
        for &b in &self.micro_batches {
            if b as f64 / power_vps * 1000.0 <= budget_ms {
                return b;
            }
        }
        self.micro_batches.last().copied().unwrap_or(self.batch_size)
    }

    /// Gradient message payload (flat f32 grads + loss + count), the unit
    /// the bandwidth model charges per §3.7 ("> 1MB for small NNs" in
    /// JSON; ours is binary f32).
    pub fn grad_message_bytes(&self) -> u64 {
        (self.param_count * 4 + 8) as u64
    }

    /// Parameter broadcast payload.
    pub fn broadcast_bytes(&self) -> u64 {
        (self.param_count * 4) as u64
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub batch_size: usize,
    pub models: BTreeMap<String, ModelSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self, String> {
        let doc = json::from_file(&dir.join("manifest.json"))?;
        Self::from_value(dir, &doc)
    }

    /// Default artifacts directory: `$MLITB_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<Self, String> {
        let dir = std::env::var("MLITB_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load(Path::new(&dir))
    }

    pub fn from_value(dir: &Path, doc: &Value) -> Result<Self, String> {
        let batch_size = doc.req_usize("batch_size")?;
        let models_v = doc
            .get("models")
            .and_then(Value::as_object)
            .ok_or("missing 'models' object")?;
        let mut models = BTreeMap::new();
        for (name, mv) in models_v {
            let mut tensors = Vec::new();
            for tv in mv.req_array("tensors")? {
                tensors.push(TensorSpec {
                    name: tv.req_str("name")?.to_string(),
                    shape: usize_list(tv, "shape")?,
                    offset: tv.req_usize("offset")?,
                    size: tv.req_usize("size")?,
                    fan_in: tv.req_usize("fan_in")?,
                });
            }
            let mut artifacts = BTreeMap::new();
            let arts = mv
                .get("artifacts")
                .and_then(Value::as_object)
                .ok_or_else(|| format!("model {name}: missing artifacts"))?;
            for (kind, av) in arts {
                artifacts.insert(kind.clone(), av.req_str("file")?.to_string());
            }
            let batch_size = mv.req_usize("batch_size")?;
            let micro_batches = if mv.get("micro_batches").is_some() {
                let mut mb = usize_list(mv, "micro_batches")?;
                mb.sort_unstable_by(|a, b| b.cmp(a));
                mb
            } else {
                vec![batch_size]
            };
            let spec = ModelSpec {
                name: name.clone(),
                param_count: mv.req_usize("param_count")?,
                batch_size,
                micro_batches,
                input: usize_list(mv, "input")?,
                classes: mv.req_usize("classes")?,
                tensors,
                artifacts,
            };
            validate(&spec)?;
            models.insert(name.clone(), spec);
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            batch_size,
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec, String> {
        self.models
            .get(name)
            .ok_or_else(|| format!("model '{name}' not in manifest (have: {:?})", self.models.keys()))
    }

    /// Absolute path of an artifact file.
    pub fn artifact_path(&self, spec: &ModelSpec, kind: &str) -> Result<PathBuf, String> {
        let file = spec
            .artifacts
            .get(kind)
            .ok_or_else(|| format!("model {}: no '{kind}' artifact", spec.name))?;
        Ok(self.dir.join(file))
    }
}

fn usize_list(v: &Value, key: &str) -> Result<Vec<usize>, String> {
    v.req_array(key)?
        .iter()
        .map(|x| {
            x.as_usize()
                .ok_or_else(|| format!("field '{key}': non-integer element"))
        })
        .collect()
}

/// Structural checks: contiguous offsets, sizes match shapes, count sums.
fn validate(spec: &ModelSpec) -> Result<(), String> {
    let mut offset = 0;
    for t in &spec.tensors {
        if t.offset != offset {
            return Err(format!("model {}: tensor {} offset gap", spec.name, t.name));
        }
        let prod: usize = t.shape.iter().product();
        if prod != t.size {
            return Err(format!("model {}: tensor {} size mismatch", spec.name, t.name));
        }
        offset += t.size;
    }
    if offset != spec.param_count {
        return Err(format!(
            "model {}: param_count {} != tensor sum {offset}",
            spec.name, spec.param_count
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;

    fn manifest_doc() -> Value {
        parse(
            r#"{
              "format": 1, "batch_size": 32,
              "models": {
                "toy": {
                  "param_count": 6, "batch_size": 32,
                  "input": [1, 2, 1], "classes": 2,
                  "layers": [],
                  "tensors": [
                    {"name": "w", "shape": [2, 2], "offset": 0, "size": 4, "fan_in": 2},
                    {"name": "b", "shape": [2], "offset": 4, "size": 2, "fan_in": 2}
                  ],
                  "artifacts": {"grad": {"file": "grad_toy.hlo.txt", "sha256": "x", "bytes": 1}}
                }
              }
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_valid_manifest() {
        let m = Manifest::from_value(Path::new("/tmp"), &manifest_doc()).unwrap();
        let spec = m.model("toy").unwrap();
        assert_eq!(spec.param_count, 6);
        assert_eq!(spec.input_len(), 2);
        assert_eq!(spec.tensors.len(), 2);
        assert_eq!(
            m.artifact_path(spec, "grad").unwrap(),
            PathBuf::from("/tmp/grad_toy.hlo.txt")
        );
        assert!(m.artifact_path(spec, "predict").is_err());
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn rejects_offset_gap() {
        let doc = parse(
            r#"{"batch_size": 1, "models": {"bad": {
                "param_count": 4, "batch_size": 1, "input": [1], "classes": 1,
                "tensors": [{"name": "w", "shape": [2], "offset": 2, "size": 2, "fan_in": 1}],
                "artifacts": {}
            }}}"#,
        )
        .unwrap();
        assert!(Manifest::from_value(Path::new("."), &doc)
            .unwrap_err()
            .contains("offset gap"));
    }

    #[test]
    fn rejects_bad_param_count() {
        let doc = parse(
            r#"{"batch_size": 1, "models": {"bad": {
                "param_count": 5, "batch_size": 1, "input": [1], "classes": 1,
                "tensors": [{"name": "w", "shape": [4], "offset": 0, "size": 4, "fan_in": 1}],
                "artifacts": {}
            }}}"#,
        )
        .unwrap();
        assert!(Manifest::from_value(Path::new("."), &doc)
            .unwrap_err()
            .contains("param_count"));
    }

    #[test]
    fn message_sizes() {
        let m = Manifest::from_value(Path::new("."), &manifest_doc()).unwrap();
        let spec = m.model("toy").unwrap();
        assert_eq!(spec.grad_message_bytes(), 6 * 4 + 8);
        assert_eq!(spec.broadcast_bytes(), 24);
    }
}
