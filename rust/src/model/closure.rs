//! Research closures — the paper's reproducibility object (§2.3, §6.4):
//! "a single object containing model and algorithm configuration plus
//! code, along with model parameters".  The prototype's JSON archive
//! stores the model spec + parameters; ours additionally records the
//! training algorithm, hyper-parameters, iteration count and optimizer —
//! everything needed to resume or verify a run (the AOT artifact hash
//! stands in for "code").

use std::path::Path;

use crate::json::{self, object, Value};
use crate::model::ModelSpec;

/// Closure format version.
pub const CLOSURE_FORMAT: u32 = 1;

/// A saved training state: model identity + parameters + algorithm.
#[derive(Debug, Clone, PartialEq)]
pub struct ResearchClosure {
    pub model_name: String,
    pub param_count: usize,
    pub params: Vec<f32>,
    pub optimizer: String,
    pub learning_rate: f32,
    pub iteration: u64,
    /// Iteration duration T (seconds) the run used (§3.3).
    pub iter_duration_s: f64,
    /// Free-form provenance notes (who trained it, on what corpus).
    pub notes: String,
}

impl ResearchClosure {
    /// Build from a live training state.
    pub fn new(spec: &ModelSpec, params: &[f32]) -> Self {
        Self {
            model_name: spec.name.clone(),
            param_count: spec.param_count,
            params: params.to_vec(),
            optimizer: "adagrad".into(),
            learning_rate: 0.01,
            iteration: 0,
            iter_duration_s: 4.0,
            notes: String::new(),
        }
    }

    /// Serialize to the JSON object (compact; params dominate the size).
    pub fn to_json(&self) -> Value {
        object(vec![
            ("format", (CLOSURE_FORMAT as i64).into()),
            ("kind", "mlitb-research-closure".into()),
            ("model", self.model_name.as_str().into()),
            ("param_count", self.param_count.into()),
            ("optimizer", self.optimizer.as_str().into()),
            ("learning_rate", (self.learning_rate as f64).into()),
            ("iteration", (self.iteration as i64).into()),
            ("iter_duration_s", self.iter_duration_s.into()),
            ("notes", self.notes.as_str().into()),
            (
                "params",
                Value::Array(
                    self.params
                        .iter()
                        .map(|&p| Value::Number(p as f64))
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse back from JSON, with structural validation.
    pub fn from_json(v: &Value) -> Result<Self, String> {
        let format = v.req_usize("format")?;
        if format as u32 > CLOSURE_FORMAT {
            return Err(format!("closure format {format} is newer than supported"));
        }
        if v.req_str("kind")? != "mlitb-research-closure" {
            return Err("not a research closure".into());
        }
        let param_count = v.req_usize("param_count")?;
        let arr = v.req_array("params")?;
        if arr.len() != param_count {
            return Err(format!(
                "closure declares {param_count} params but carries {}",
                arr.len()
            ));
        }
        let mut params = Vec::with_capacity(arr.len());
        for (i, x) in arr.iter().enumerate() {
            let f = x
                .as_f64()
                .ok_or_else(|| format!("param {i} is not a number"))?;
            if !f.is_finite() {
                return Err(format!("param {i} is not finite"));
            }
            params.push(f as f32);
        }
        Ok(Self {
            model_name: v.req_str("model")?.to_string(),
            param_count,
            params,
            optimizer: v.req_str("optimizer")?.to_string(),
            learning_rate: v.req_f64("learning_rate")? as f32,
            iteration: v.req_usize("iteration")? as u64,
            iter_duration_s: v.req_f64("iter_duration_s")?,
            notes: v.req_str("notes")?.to_string(),
        })
    }

    /// Save to a file (pretty JSON — human-readable as the paper intends).
    pub fn save(&self, path: &Path) -> Result<(), String> {
        std::fs::write(path, json::to_string_pretty(&self.to_json()))
            .map_err(|e| format!("write {}: {e}", path.display()))
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Self, String> {
        Self::from_json(&json::from_file(path)?)
    }

    /// Check compatibility against a manifest spec before resuming.
    pub fn check_compatible(&self, spec: &ModelSpec) -> Result<(), String> {
        if self.model_name != spec.name {
            return Err(format!(
                "closure is for model '{}', artifact is '{}'",
                self.model_name, spec.name
            ));
        }
        if self.param_count != spec.param_count {
            return Err(format!(
                "closure has {} params, artifact expects {}",
                self.param_count, spec.param_count
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TensorSpec;

    fn spec() -> ModelSpec {
        ModelSpec {
            name: "toy".into(),
            param_count: 4,
            batch_size: 2,
            micro_batches: vec![2],
            input: vec![2, 1, 1],
            classes: 2,
            tensors: vec![TensorSpec {
                name: "w".into(),
                shape: vec![4],
                offset: 0,
                size: 4,
                fan_in: 2,
            }],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn json_roundtrip_exact() {
        let mut c = ResearchClosure::new(&spec(), &[0.1, -0.25, 3.5e-8, 0.0]);
        c.iteration = 42;
        c.notes = "trained on synth-mnist".into();
        let back = ResearchClosure::from_json(&c.to_json()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn file_roundtrip() {
        let c = ResearchClosure::new(&spec(), &[1.0, 2.0, 3.0, 4.0]);
        let path = std::env::temp_dir().join("mlitb_closure_test.json");
        c.save(&path).unwrap();
        let back = ResearchClosure::load(&path).unwrap();
        assert_eq!(c, back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_param_count_mismatch() {
        let c = ResearchClosure::new(&spec(), &[1.0, 2.0, 3.0, 4.0]);
        let mut v = c.to_json();
        if let Value::Object(o) = &mut v {
            o.insert("param_count".into(), 3.into());
        }
        assert!(ResearchClosure::from_json(&v).is_err());
    }

    #[test]
    fn rejects_wrong_kind() {
        let v = crate::json::object(vec![("format", 1.into()), ("kind", "x".into())]);
        assert!(ResearchClosure::from_json(&v).is_err());
    }

    #[test]
    fn compatibility_checks() {
        let c = ResearchClosure::new(&spec(), &[0.0; 4]);
        assert!(c.check_compatible(&spec()).is_ok());
        let mut other = spec();
        other.param_count = 8;
        assert!(c.check_compatible(&other).is_err());
        let mut renamed = spec();
        renamed.name = "other".into();
        assert!(c.check_compatible(&renamed).is_err());
    }

    #[test]
    fn rejects_nonfinite_params() {
        let c = ResearchClosure::new(&spec(), &[1.0, 2.0, 3.0, 4.0]);
        let mut v = c.to_json();
        if let Value::Object(o) = &mut v {
            // NaN serializes as null → parse will reject as non-number
            o.insert(
                "params".into(),
                Value::Array(vec![1.into(), 2.into(), Value::Null, 4.into()]),
            );
        }
        assert!(ResearchClosure::from_json(&v).is_err());
    }
}
