//! Model metadata: the AOT manifest (mirror of `python/compile/model.py`)
//! and the paper's JSON **research closures** (§2.3, §3.6: "users can
//! download the entire model specification and current parameter values in
//! JSON format ... and initialize a new training session by uploading it").

mod closure;
mod manifest;

pub use closure::{ResearchClosure, CLOSURE_FORMAT};
pub use manifest::{Manifest, ModelSpec, TensorSpec};

use crate::rng::{Normal, Pcg32};

/// Initialize a flat parameter vector from the manifest layout: LeCun
/// normal (σ = 1/√fan_in) for weights, zeros for biases — matching
/// `model.init_params` on the Python side so closures are interchangeable.
pub fn init_params(spec: &ModelSpec, seed: u64) -> Vec<f32> {
    let mut out = vec![0.0f32; spec.param_count];
    let mut rng = Pcg32::new(seed ^ 0x1217);
    for t in &spec.tensors {
        if t.name.ends_with("_b") {
            continue; // biases stay zero
        }
        let dist = Normal::new(0.0, 1.0 / (t.fan_in as f64).sqrt());
        for slot in &mut out[t.offset..t.offset + t.size] {
            *slot = dist.sample(&mut rng) as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_spec() -> ModelSpec {
        ModelSpec {
            name: "toy".into(),
            param_count: 30,
            batch_size: 4,
            micro_batches: vec![4],
            input: vec![2, 2, 1],
            classes: 2,
            tensors: vec![
                TensorSpec {
                    name: "l0_fc_w".into(),
                    shape: vec![4, 5],
                    offset: 0,
                    size: 20,
                    fan_in: 4,
                },
                TensorSpec {
                    name: "l0_fc_b".into(),
                    shape: vec![5],
                    offset: 20,
                    size: 5,
                    fan_in: 4,
                },
                TensorSpec {
                    name: "l1_fc_w".into(),
                    shape: vec![5, 1],
                    offset: 25,
                    size: 5,
                    fan_in: 5,
                },
            ],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn init_zeros_biases_and_scales_weights() {
        let spec = toy_spec();
        let p = init_params(&spec, 1);
        assert_eq!(p.len(), 30);
        assert!(p[20..25].iter().all(|&x| x == 0.0), "biases nonzero");
        let w_norm: f32 = p[0..20].iter().map(|x| x * x).sum();
        assert!(w_norm > 0.0);
        // deterministic per seed
        assert_eq!(p, init_params(&spec, 1));
        assert_ne!(p, init_params(&spec, 2));
    }
}
