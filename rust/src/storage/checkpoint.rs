//! Checkpoint frames: full [`SimState`] snapshots with atomic commit.
//!
//! A checkpoint is one file `ckpt-<iteration>.bin`:
//!
//! ```text
//! MLCK | version:u32 | seed:u64 | config_digest:u64 | iteration:u64 |
//! len:u32 | crc32:u32 | encoded SimState
//! ```
//!
//! Commit protocol: write to `<name>.tmp`, `sync_data`, rename onto the
//! final name, then best-effort sync the directory.  A crash mid-write
//! leaves only a `.tmp` the loader ignores; a bit-flip fails the CRC and
//! the loader falls back to the next-older checkpoint.  Files are never
//! modified after the rename.

use std::fs::{self, File};
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::allocation::{AllocatorState, WorkerAllocState};
use crate::client::ClientState;
use crate::coordinator::{MasterState, PayloadState, SubmissionState};
use crate::data::{CacheEntryState, CacheState};
use crate::metrics::IterationRecord;
use crate::sim::SimState;

use super::frame::{frame, read_frame, ByteReader, ByteWriter, FrameRead, Result, StorageError};
use super::wal::RunIdentity;

pub const CKPT_MAGIC: &[u8; 4] = b"MLCK";
/// v2: master frame carries sanitation strike counters (robustness plane).
pub const CKPT_VERSION: u32 = 2;
/// magic + version + seed + config_digest + iteration
const HEADER_LEN: usize = 4 + 4 + 8 + 8 + 8;

/// File name for the checkpoint taken at `iteration` (zero-padded so the
/// lexicographic and numeric orders agree).
pub fn checkpoint_file_name(iteration: u64) -> String {
    format!("ckpt-{iteration:010}.bin")
}

// ------------------------------------------------------------- encoding

fn encode_allocator(w: &mut ByteWriter, a: &AllocatorState) {
    w.put_u64(a.capacity as u64);
    w.put_u64(a.total_data);
    w.put_u32(a.workers.len() as u32);
    for ws in &a.workers {
        w.put_u64(ws.id);
        w.put_u32s(&ws.owned);
        w.put_u32s(&ws.cached);
    }
    w.put_u32s(&a.unallocated);
    w.put_u64(a.transfers);
}

fn decode_allocator(r: &mut ByteReader<'_>) -> Result<AllocatorState> {
    let capacity = r.get_u64()? as usize;
    let total_data = r.get_u64()?;
    let n = r.get_u32()?;
    let mut workers = Vec::with_capacity(n as usize);
    for _ in 0..n {
        workers.push(WorkerAllocState {
            id: r.get_u64()?,
            owned: r.get_u32s()?,
            cached: r.get_u32s()?,
        });
    }
    Ok(AllocatorState {
        capacity,
        total_data,
        workers,
        unallocated: r.get_u32s()?,
        transfers: r.get_u64()?,
    })
}

fn encode_record(w: &mut ByteWriter, rec: &IterationRecord) {
    w.put_u64(rec.iteration);
    w.put_f64(rec.t_virtual_ms);
    w.put_u64(rec.vectors);
    w.put_u32(rec.workers);
    w.put_f64(rec.mean_latency_ms);
    w.put_f64(rec.max_latency_ms);
    w.put_opt_f64(rec.loss);
    w.put_opt_f64(rec.test_error);
    w.put_u64(rec.bytes_up);
    w.put_u64(rec.bytes_down);
}

fn decode_record(r: &mut ByteReader<'_>) -> Result<IterationRecord> {
    Ok(IterationRecord {
        iteration: r.get_u64()?,
        t_virtual_ms: r.get_f64()?,
        vectors: r.get_u64()?,
        workers: r.get_u32()?,
        mean_latency_ms: r.get_f64()?,
        max_latency_ms: r.get_f64()?,
        loss: r.get_opt_f64()?,
        test_error: r.get_opt_f64()?,
        bytes_up: r.get_u64()?,
        bytes_down: r.get_u64()?,
    })
}

fn encode_submission(w: &mut ByteWriter, s: &SubmissionState) {
    w.put_u64(s.worker);
    match &s.payload {
        PayloadState::Dense(g) => {
            w.put_u8(0);
            w.put_f32s(g);
        }
        PayloadState::Sparse(entries) => {
            w.put_u8(1);
            w.put_u32(entries.len() as u32);
            for &(i, v) in entries {
                w.put_u32(i);
                w.put_f32(v);
            }
        }
    }
    w.put_u64(s.examples);
    w.put_u64(s.vectors);
    w.put_f64(s.loss_sum);
    w.put_f64(s.send_offset_ms);
    w.put_u64(s.bytes);
}

fn decode_submission(r: &mut ByteReader<'_>) -> Result<SubmissionState> {
    let worker = r.get_u64()?;
    let payload = match r.get_u8()? {
        0 => PayloadState::Dense(r.get_f32s()?),
        1 => {
            let n = r.get_u32()? as usize;
            let mut entries = Vec::with_capacity(n);
            for _ in 0..n {
                entries.push((r.get_u32()?, r.get_f32()?));
            }
            PayloadState::Sparse(entries)
        }
        t => {
            return Err(StorageError::Corrupt(format!("bad payload tag {t}")));
        }
    };
    Ok(SubmissionState {
        worker,
        payload,
        examples: r.get_u64()?,
        vectors: r.get_u64()?,
        loss_sum: r.get_f64()?,
        send_offset_ms: r.get_f64()?,
        bytes: r.get_u64()?,
    })
}

fn encode_master(w: &mut ByteWriter, m: &MasterState) {
    w.put_u64(m.iteration);
    w.put_f64(m.t_virtual_ms);
    w.put_f32s(&m.params);
    w.put_str(&m.optimizer);
    w.put_f32s(&m.opt_state);
    encode_allocator(w, &m.allocator);
    w.put_u32(m.latency.len() as u32);
    for &(worker, est) in &m.latency {
        w.put_u64(worker);
        w.put_f64(est);
    }
    w.put_u32(m.timeline.len() as u32);
    for rec in &m.timeline {
        encode_record(w, rec);
    }
    w.put_u32(m.carryover.len() as u32);
    for s in &m.carryover {
        encode_submission(w, s);
    }
    w.put_opt_f64(m.pending_test_error);
    w.put_u32(m.strikes.len() as u32);
    for &(worker, n) in &m.strikes {
        w.put_u64(worker);
        w.put_u32(n);
    }
}

fn decode_master(r: &mut ByteReader<'_>) -> Result<MasterState> {
    let iteration = r.get_u64()?;
    let t_virtual_ms = r.get_f64()?;
    let params = r.get_f32s()?;
    let optimizer = r.get_str()?;
    let opt_state = r.get_f32s()?;
    let allocator = decode_allocator(r)?;
    let n = r.get_u32()?;
    let mut latency = Vec::with_capacity(n as usize);
    for _ in 0..n {
        latency.push((r.get_u64()?, r.get_f64()?));
    }
    let n = r.get_u32()?;
    let mut timeline = Vec::with_capacity(n as usize);
    for _ in 0..n {
        timeline.push(decode_record(r)?);
    }
    let n = r.get_u32()?;
    let mut carryover = Vec::with_capacity(n as usize);
    for _ in 0..n {
        carryover.push(decode_submission(r)?);
    }
    let pending_test_error = r.get_opt_f64()?;
    let n = r.get_u32()?;
    let mut strikes = Vec::with_capacity(n as usize);
    for _ in 0..n {
        strikes.push((r.get_u64()?, r.get_u32()?));
    }
    Ok(MasterState {
        iteration,
        t_virtual_ms,
        params,
        optimizer,
        opt_state,
        allocator,
        latency,
        timeline,
        carryover,
        pending_test_error,
        strikes,
    })
}

fn encode_client(w: &mut ByteWriter, c: &ClientState) {
    w.put_u64(c.id);
    w.put_str(c.class.name());
    w.put_f64(c.power_vps);
    w.put_str(c.link_profile.name());
    w.put_f64(c.link_base_ms);
    w.put_u64(c.rng_state);
    w.put_u64(c.rng_inc);
    w.put_u32s(&c.owned);
    w.put_u32s(&c.pending);
    w.put_u64(c.cursor);
    w.put_u64(c.cache.tick);
    w.put_u32(c.cache.entries.len() as u32);
    for e in &c.cache.entries {
        w.put_u64(e.last_used);
        w.put_u32(e.id);
        w.put_u8(u8::from(e.pinned));
    }
}

fn decode_client(r: &mut ByteReader<'_>) -> Result<ClientState> {
    let id = r.get_u64()?;
    let class = crate::client::DeviceClass::parse(&r.get_str()?)
        .map_err(StorageError::Corrupt)?;
    let power_vps = r.get_f64()?;
    let link_profile = crate::netsim::LinkProfile::parse(&r.get_str()?)
        .map_err(StorageError::Corrupt)?;
    let link_base_ms = r.get_f64()?;
    let rng_state = r.get_u64()?;
    let rng_inc = r.get_u64()?;
    let owned = r.get_u32s()?;
    let pending = r.get_u32s()?;
    let cursor = r.get_u64()?;
    let tick = r.get_u64()?;
    let n = r.get_u32()?;
    let mut entries = Vec::with_capacity(n as usize);
    for _ in 0..n {
        entries.push(CacheEntryState {
            last_used: r.get_u64()?,
            id: r.get_u32()?,
            pinned: match r.get_u8()? {
                0 => false,
                1 => true,
                t => {
                    return Err(StorageError::Corrupt(format!("bad pin flag {t}")));
                }
            },
        });
    }
    Ok(ClientState {
        id,
        class,
        power_vps,
        link_profile,
        link_base_ms,
        rng_state,
        rng_inc,
        owned,
        pending,
        cursor,
        cache: CacheState { tick, entries },
    })
}

/// Encode a full [`SimState`] into a flat payload (what the CRC frame
/// wraps).
pub fn encode_state(st: &SimState) -> Vec<u8> {
    let mut w = ByteWriter::new();
    encode_master(&mut w, &st.master);
    w.put_u32(st.clients.len() as u32);
    for c in &st.clients {
        encode_client(&mut w, c);
    }
    w.put_u64(st.next_worker_id);
    w.put_u64(st.rng.0);
    w.put_u64(st.rng.1);
    w.finish()
}

/// Decode a payload produced by [`encode_state`].
pub fn decode_state(payload: &[u8]) -> Result<SimState> {
    let mut r = ByteReader::new(payload);
    let master = decode_master(&mut r)?;
    let n = r.get_u32()?;
    let mut clients = Vec::with_capacity(n as usize);
    for _ in 0..n {
        clients.push(decode_client(&mut r)?);
    }
    let st = SimState {
        master,
        clients,
        next_worker_id: r.get_u64()?,
        rng: (r.get_u64()?, r.get_u64()?),
    };
    r.expect_end()?;
    Ok(st)
}

// ------------------------------------------------------------- file I/O

/// Write the checkpoint for `st` into `dir` atomically; returns the final
/// path.  Safe against crashes at any point: the final name appears only
/// complete and CRC-valid.
pub fn write_checkpoint(dir: &Path, identity: RunIdentity, st: &SimState) -> Result<PathBuf> {
    let name = checkpoint_file_name(st.master.iteration);
    let final_path = dir.join(&name);
    let tmp_path = dir.join(format!("{name}.tmp"));

    let mut bytes = Vec::with_capacity(HEADER_LEN);
    bytes.extend_from_slice(CKPT_MAGIC);
    bytes.extend_from_slice(&CKPT_VERSION.to_le_bytes());
    bytes.extend_from_slice(&identity.seed.to_le_bytes());
    bytes.extend_from_slice(&identity.config_digest.to_le_bytes());
    bytes.extend_from_slice(&st.master.iteration.to_le_bytes());
    bytes.extend_from_slice(&frame(&encode_state(st)));

    let mut f = File::create(&tmp_path)?;
    f.write_all(&bytes)?;
    f.sync_data()?;
    drop(f);
    fs::rename(&tmp_path, &final_path)?;
    // Directory-entry durability for the rename; not supported on every
    // filesystem, and the checkpoint is still valid without it.
    let _ = File::open(dir).and_then(|d| d.sync_all());
    Ok(final_path)
}

/// Read and validate one checkpoint file.
pub fn read_checkpoint(path: &Path) -> Result<(RunIdentity, SimState)> {
    let bytes = fs::read(path)?;
    if bytes.len() < HEADER_LEN {
        return Err(StorageError::Corrupt(format!(
            "checkpoint too short ({} bytes)",
            bytes.len()
        )));
    }
    if &bytes[..4] != CKPT_MAGIC {
        return Err(StorageError::Corrupt("bad checkpoint magic".into()));
    }
    let version = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]);
    if version != CKPT_VERSION {
        return Err(StorageError::Corrupt(format!(
            "unsupported checkpoint version {version}"
        )));
    }
    let mut r = ByteReader::new(&bytes[8..HEADER_LEN]);
    let identity = RunIdentity {
        seed: r.get_u64()?,
        config_digest: r.get_u64()?,
    };
    let iteration = r.get_u64()?;

    match read_frame(&bytes, HEADER_LEN) {
        FrameRead::Ok { payload, consumed } => {
            if HEADER_LEN + consumed != bytes.len() {
                return Err(StorageError::Corrupt(format!(
                    "{} trailing bytes after checkpoint frame",
                    bytes.len() - HEADER_LEN - consumed
                )));
            }
            let st = decode_state(payload)?;
            if st.master.iteration != iteration {
                return Err(StorageError::Corrupt(format!(
                    "header says iteration {iteration}, payload says {}",
                    st.master.iteration
                )));
            }
            Ok((identity, st))
        }
        FrameRead::End => Err(StorageError::Corrupt("checkpoint has no frame".into())),
        FrameRead::Torn { reason, .. } => Err(StorageError::Corrupt(reason)),
    }
}

/// Iterations with a committed checkpoint file in `dir`, ascending.
/// `.tmp` leftovers and foreign files are ignored.  Determinism audit:
/// `read_dir` order is OS-dependent; the result is sorted before it can
/// reach recovery decisions or any observable state.
pub fn checkpoint_iterations(dir: &Path) -> Result<Vec<u64>> {
    let mut out = Vec::new();
    for entry in fs::read_dir(dir)? {
        let name = entry?.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some(digits) = name
            .strip_prefix("ckpt-")
            .and_then(|s| s.strip_suffix(".bin"))
        {
            if let Ok(it) = digits.parse::<u64>() {
                out.push(it);
            }
        }
    }
    out.sort_unstable();
    Ok(out)
}

/// Load the newest checkpoint that validates and matches `identity`.
/// Corrupt or foreign files are skipped with a warning (newest-first
/// fallback), not treated as fatal: an older good checkpoint plus a
/// longer replay still recovers the run.
pub fn load_latest_checkpoint(
    dir: &Path,
    identity: RunIdentity,
) -> Result<(Option<SimState>, Vec<String>)> {
    let mut warnings = Vec::new();
    for it in checkpoint_iterations(dir)?.into_iter().rev() {
        let path = dir.join(checkpoint_file_name(it));
        match read_checkpoint(&path) {
            Ok((id, st)) if id == identity => return Ok((Some(st), warnings)),
            Ok((id, _)) => warnings.push(format!(
                "{}: belongs to a different run (seed {} config {:#x})",
                path.display(),
                id.seed,
                id.config_digest
            )),
            Err(e) => warnings.push(format!("{}: {e}", path.display())),
        }
    }
    Ok((None, warnings))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::DeviceClass;
    use crate::netsim::LinkProfile;

    fn test_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("mlitb-ckpt-{}-{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample_state(iteration: u64) -> SimState {
        SimState {
            master: MasterState {
                iteration,
                t_virtual_ms: iteration as f64 * 4000.0 + 0.125,
                params: vec![0.5, -0.0, 3.25e-7],
                optimizer: "adagrad".into(),
                opt_state: vec![0.01, 0.02, 0.03],
                allocator: AllocatorState {
                    capacity: 100,
                    total_data: 7,
                    workers: vec![
                        WorkerAllocState {
                            id: 1,
                            owned: vec![0, 2, 4],
                            cached: vec![0, 4],
                        },
                        WorkerAllocState {
                            id: 3,
                            owned: vec![1, 3],
                            cached: vec![],
                        },
                    ],
                    unallocated: vec![5, 6],
                    transfers: 9,
                },
                latency: vec![(1, 52.5), (3, 461.0)],
                timeline: vec![IterationRecord {
                    iteration: 0,
                    t_virtual_ms: 4000.0,
                    vectors: 31,
                    workers: 2,
                    mean_latency_ms: 12.0,
                    max_latency_ms: 30.0,
                    loss: Some(2.3),
                    test_error: None,
                    bytes_up: 4096,
                    bytes_down: 8192,
                }],
                carryover: vec![
                    SubmissionState {
                        worker: 3,
                        payload: PayloadState::Dense(vec![1.0, -1.0, 0.5]),
                        examples: 4,
                        vectors: 4,
                        loss_sum: 3.2,
                        send_offset_ms: 6100.0,
                        bytes: 108,
                    },
                    SubmissionState {
                        worker: 1,
                        payload: PayloadState::Sparse(vec![(0, 0.25), (2, -4.0)]),
                        examples: 2,
                        vectors: 2,
                        loss_sum: 1.1,
                        send_offset_ms: 7000.0,
                        bytes: 112,
                    },
                ],
                pending_test_error: Some(0.87),
                strikes: vec![(3, 2)],
            },
            clients: vec![ClientState {
                id: 1,
                class: DeviceClass::Mobile,
                power_vps: 218.75,
                link_profile: LinkProfile::Cellular,
                link_base_ms: 81.5,
                rng_state: 0xDEAD_BEEF_0123_4567,
                rng_inc: 0x9E37_79B9_7F4A_7C15 | 1,
                owned: vec![0, 2, 4],
                pending: vec![4],
                cursor: 11,
                cache: CacheState {
                    tick: 14,
                    entries: vec![
                        CacheEntryState {
                            last_used: 12,
                            id: 0,
                            pinned: true,
                        },
                        CacheEntryState {
                            last_used: 14,
                            id: 2,
                            pinned: false,
                        },
                    ],
                },
            }],
            next_worker_id: 4,
            rng: (0x1234_5678_9ABC_DEF0, 0xFEDC_BA98_7654_3211),
        }
    }

    #[test]
    fn encode_decode_roundtrip_is_lossless() {
        let st = sample_state(2);
        let payload = encode_state(&st);
        assert_eq!(decode_state(&payload).unwrap(), st);
    }

    #[test]
    fn zero_everything_state_roundtrips() {
        // Zero-param spec, no clients, empty allocator: the degenerate
        // project must still checkpoint and load.
        let st = SimState {
            master: MasterState {
                iteration: 0,
                t_virtual_ms: 0.0,
                params: vec![],
                optimizer: "sgd".into(),
                opt_state: vec![],
                allocator: AllocatorState {
                    capacity: 10,
                    total_data: 0,
                    workers: vec![],
                    unallocated: vec![],
                    transfers: 0,
                },
                latency: vec![],
                timeline: vec![],
                carryover: vec![],
                pending_test_error: None,
                strikes: vec![],
            },
            clients: vec![],
            next_worker_id: 1,
            rng: (1, 3),
        };
        let payload = encode_state(&st);
        assert_eq!(decode_state(&payload).unwrap(), st);
    }

    #[test]
    fn write_load_roundtrip_and_newest_wins() {
        let dir = test_dir("roundtrip");
        let id = RunIdentity {
            seed: 5,
            config_digest: 77,
        };
        write_checkpoint(&dir, id, &sample_state(2)).unwrap();
        write_checkpoint(&dir, id, &sample_state(6)).unwrap();
        assert_eq!(checkpoint_iterations(&dir).unwrap(), vec![2, 6]);
        let (st, warnings) = load_latest_checkpoint(&dir, id).unwrap();
        assert_eq!(st.unwrap().master.iteration, 6);
        assert!(warnings.is_empty(), "{warnings:?}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_newest_falls_back_to_older() {
        let dir = test_dir("fallback");
        let id = RunIdentity {
            seed: 5,
            config_digest: 77,
        };
        write_checkpoint(&dir, id, &sample_state(2)).unwrap();
        let newest = write_checkpoint(&dir, id, &sample_state(6)).unwrap();
        // Flip one payload byte in the newest file: CRC must catch it.
        let mut bytes = fs::read(&newest).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40;
        fs::write(&newest, bytes).unwrap();
        let (st, warnings) = load_latest_checkpoint(&dir, id).unwrap();
        assert_eq!(st.unwrap().master.iteration, 2);
        assert_eq!(warnings.len(), 1);
        assert!(warnings[0].contains("ckpt-0000000006"), "{warnings:?}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn foreign_identity_is_skipped_and_tmp_ignored() {
        let dir = test_dir("identity");
        let ours = RunIdentity {
            seed: 5,
            config_digest: 77,
        };
        let theirs = RunIdentity {
            seed: 6,
            config_digest: 77,
        };
        write_checkpoint(&dir, theirs, &sample_state(9)).unwrap();
        fs::write(dir.join("ckpt-0000000099.bin.tmp"), b"partial").unwrap();
        assert_eq!(checkpoint_iterations(&dir).unwrap(), vec![9]);
        let (st, warnings) = load_latest_checkpoint(&dir, ours).unwrap();
        assert!(st.is_none());
        assert_eq!(warnings.len(), 1);
        assert!(warnings[0].contains("different run"), "{warnings:?}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_checkpoint_is_reported_corrupt() {
        let dir = test_dir("torn");
        let id = RunIdentity {
            seed: 5,
            config_digest: 77,
        };
        let path = write_checkpoint(&dir, id, &sample_state(3)).unwrap();
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..bytes.len() - 10]).unwrap();
        assert!(read_checkpoint(&path).is_err());
        let (st, warnings) = load_latest_checkpoint(&dir, id).unwrap();
        assert!(st.is_none());
        assert_eq!(warnings.len(), 1);
        let _ = fs::remove_dir_all(&dir);
    }
}
