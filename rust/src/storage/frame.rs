//! Byte-level codec for the durable state plane: little-endian
//! reader/writer, CRC-32 framing, and the FNV-1a content digests the WAL
//! uses to pin replay to bitwise-identical state.
//!
//! Everything here is dependency-free and deterministic: the same state
//! encodes to the same bytes on every platform (explicit little-endian,
//! no hashes over pointer-order collections), which is what lets a WAL
//! written on one run verify a replay on another.

use std::fmt;

/// Errors from the storage plane. I/O errors carry the OS error;
/// `Corrupt` means bytes were read but failed structural or CRC checks.
#[derive(Debug)]
pub enum StorageError {
    Io(std::io::Error),
    Corrupt(String),
}

impl fmt::Display for StorageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StorageError::Io(e) => write!(f, "storage i/o error: {e}"),
            StorageError::Corrupt(msg) => write!(f, "corrupt storage: {msg}"),
        }
    }
}

impl std::error::Error for StorageError {}

impl From<std::io::Error> for StorageError {
    fn from(e: std::io::Error) -> Self {
        StorageError::Io(e)
    }
}

/// Shorthand used across the storage modules.
pub type Result<T> = std::result::Result<T, StorageError>;

// ---------------------------------------------------------------- CRC-32

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 {
                0xEDB8_8320 ^ (c >> 1)
            } else {
                c >> 1
            };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const CRC_TABLE: [u32; 256] = crc32_table();

/// CRC-32 (IEEE reflected polynomial) — the per-frame integrity check on
/// WAL records, checkpoints and registry segments.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ------------------------------------------------------------- FNV-1a 64

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Streaming FNV-1a 64-bit digest — the content fingerprint the WAL
/// records for merged gradients, worker sets and parameter vectors.
/// Not cryptographic; it only needs to make a replay divergence loud.
#[derive(Debug, Clone, Copy)]
pub struct Fnv64 {
    h: u64,
}

impl Fnv64 {
    pub fn new() -> Self {
        Self { h: FNV_OFFSET }
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.h ^= b as u64;
            self.h = self.h.wrapping_mul(FNV_PRIME);
        }
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub fn write_f32s(&mut self, vs: &[f32]) {
        for v in vs {
            self.write(&v.to_le_bytes());
        }
    }

    pub fn finish(&self) -> u64 {
        self.h
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot digest over a byte slice.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.write(bytes);
    h.finish()
}

/// Digest of a flat f32 vector (LE byte order) — bitwise, so two vectors
/// digest equal iff every element is bit-identical (NaN payloads included).
pub fn digest_f32s(vs: &[f32]) -> u64 {
    let mut h = Fnv64::new();
    h.write_f32s(vs);
    h.finish()
}

// ------------------------------------------------------------ ByteWriter

/// Append-only little-endian encoder backing every on-disk payload.
#[derive(Debug, Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Length-prefixed f32 vector.
    pub fn put_f32s(&mut self, vs: &[f32]) {
        self.put_u32(vs.len() as u32);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// Length-prefixed u32 vector.
    pub fn put_u32s(&mut self, vs: &[u32]) {
        self.put_u32(vs.len() as u32);
        for &v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// Length-prefixed u64 vector.
    pub fn put_u64s(&mut self, vs: &[u64]) {
        self.put_u32(vs.len() as u32);
        for &v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    pub fn put_opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(x) => {
                self.put_u8(1);
                self.put_f64(x);
            }
            None => self.put_u8(0),
        }
    }

    pub fn put_opt_u64(&mut self, v: Option<u64>) {
        match v {
            Some(x) => {
                self.put_u8(1);
                self.put_u64(x);
            }
            None => self.put_u8(0),
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

// ------------------------------------------------------------ ByteReader

/// Cursor-based decoder over an in-memory payload; every read is
/// bounds-checked and returns `Corrupt` instead of panicking, so a torn
/// or bit-flipped frame degrades to a recoverable error.
#[derive(Debug)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(StorageError::Corrupt(format!(
                "payload truncated: need {} bytes at offset {}, have {}",
                n,
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.get_u32()?))
    }

    pub fn get_str(&mut self) -> Result<String> {
        let n = self.get_u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| StorageError::Corrupt("invalid utf-8 in string field".into()))
    }

    pub fn get_f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.get_u32()? as usize;
        let bytes = self.take(n.checked_mul(4).ok_or_else(|| {
            StorageError::Corrupt("f32 vector length overflow".into())
        })?)?;
        let mut out = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(out)
    }

    pub fn get_u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.get_u32()? as usize;
        let bytes = self.take(n.checked_mul(4).ok_or_else(|| {
            StorageError::Corrupt("u32 vector length overflow".into())
        })?)?;
        let mut out = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            out.push(u32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(out)
    }

    pub fn get_u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.get_u32()? as usize;
        let bytes = self.take(n.checked_mul(8).ok_or_else(|| {
            StorageError::Corrupt("u64 vector length overflow".into())
        })?)?;
        let mut out = Vec::with_capacity(n);
        for c in bytes.chunks_exact(8) {
            out.push(u64::from_le_bytes([
                c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7],
            ]));
        }
        Ok(out)
    }

    pub fn get_opt_f64(&mut self) -> Result<Option<f64>> {
        match self.get_u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.get_f64()?)),
            t => Err(StorageError::Corrupt(format!("bad option tag {t}"))),
        }
    }

    pub fn get_opt_u64(&mut self) -> Result<Option<u64>> {
        match self.get_u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.get_u64()?)),
            t => Err(StorageError::Corrupt(format!("bad option tag {t}"))),
        }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Decoders call this last: trailing bytes mean a format mismatch.
    pub fn expect_end(&self) -> Result<()> {
        if self.remaining() != 0 {
            return Err(StorageError::Corrupt(format!(
                "{} trailing bytes after payload",
                self.remaining()
            )));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------- frames

/// Wrap a payload in the on-disk frame: `len:u32 | crc32:u32 | payload`.
/// The CRC covers the payload only; the length prefix is what lets a
/// reader detect a torn tail (fewer bytes on disk than the header claims).
pub fn frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Outcome of pulling one frame off a byte stream.
#[derive(Debug)]
pub enum FrameRead<'a> {
    /// A complete, CRC-clean payload (and the bytes consumed).
    Ok { payload: &'a [u8], consumed: usize },
    /// Stream ended exactly on a frame boundary.
    End,
    /// Bytes remain but do not form a whole valid frame — a torn or
    /// corrupt tail. `valid_up_to` is the offset the stream is good to.
    Torn { valid_up_to: usize, reason: String },
}

/// Read the frame starting at `offset`; never panics on short input.
pub fn read_frame(buf: &[u8], offset: usize) -> FrameRead<'_> {
    let rest = &buf[offset..];
    if rest.is_empty() {
        return FrameRead::End;
    }
    if rest.len() < 8 {
        return FrameRead::Torn {
            valid_up_to: offset,
            reason: format!("{} bytes of partial frame header", rest.len()),
        };
    }
    let len = u32::from_le_bytes([rest[0], rest[1], rest[2], rest[3]]) as usize;
    let want_crc = u32::from_le_bytes([rest[4], rest[5], rest[6], rest[7]]);
    if rest.len() < 8 + len {
        return FrameRead::Torn {
            valid_up_to: offset,
            reason: format!(
                "frame claims {} payload bytes, only {} on disk",
                len,
                rest.len() - 8
            ),
        };
    }
    let payload = &rest[8..8 + len];
    let got_crc = crc32(payload);
    if got_crc != want_crc {
        return FrameRead::Torn {
            valid_up_to: offset,
            reason: format!("crc mismatch: stored {want_crc:#010x}, computed {got_crc:#010x}"),
        };
    }
    FrameRead::Ok {
        payload,
        consumed: 8 + len,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vector() {
        // The classic check value for "123456789" under CRC-32/IEEE.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn fnv_is_order_sensitive_and_stable() {
        assert_eq!(fnv1a64(b""), FNV_OFFSET);
        assert_ne!(fnv1a64(b"ab"), fnv1a64(b"ba"));
        let a = digest_f32s(&[1.0, 2.0, 3.0]);
        let b = digest_f32s(&[1.0, 2.0, 3.0]);
        assert_eq!(a, b);
        assert_ne!(a, digest_f32s(&[1.0, 2.0, 3.0000001]));
        // Bitwise: -0.0 and 0.0 are different bytes, so different digests.
        assert_ne!(digest_f32s(&[0.0]), digest_f32s(&[-0.0]));
    }

    #[test]
    fn writer_reader_roundtrip() {
        let mut w = ByteWriter::new();
        w.put_u8(7);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX);
        w.put_f64(-1.5);
        w.put_str("hello");
        w.put_f32s(&[1.0, f32::NAN, -0.0]);
        w.put_u32s(&[1, 2, 3]);
        w.put_u64s(&[9, 8]);
        w.put_opt_f64(Some(2.5));
        w.put_opt_f64(None);
        w.put_opt_u64(Some(42));
        let bytes = w.finish();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX);
        assert_eq!(r.get_f64().unwrap(), -1.5);
        assert_eq!(r.get_str().unwrap(), "hello");
        let fs = r.get_f32s().unwrap();
        assert_eq!(fs[0], 1.0);
        assert!(fs[1].is_nan());
        assert_eq!(fs[2].to_bits(), (-0.0f32).to_bits());
        assert_eq!(r.get_u32s().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.get_u64s().unwrap(), vec![9, 8]);
        assert_eq!(r.get_opt_f64().unwrap(), Some(2.5));
        assert_eq!(r.get_opt_f64().unwrap(), None);
        assert_eq!(r.get_opt_u64().unwrap(), Some(42));
        r.expect_end().unwrap();
    }

    #[test]
    fn reader_truncation_is_an_error_not_a_panic() {
        let mut w = ByteWriter::new();
        w.put_u64(1);
        let bytes = w.finish();
        let mut r = ByteReader::new(&bytes[..4]);
        assert!(r.get_u64().is_err());
    }

    #[test]
    fn frame_roundtrip_and_torn_detection() {
        let f1 = frame(b"first");
        let f2 = frame(b"second");
        let mut stream = f1.clone();
        stream.extend_from_slice(&f2);

        match read_frame(&stream, 0) {
            FrameRead::Ok { payload, consumed } => {
                assert_eq!(payload, b"first");
                assert_eq!(consumed, f1.len());
            }
            other => panic!("expected Ok, got {other:?}"),
        }
        match read_frame(&stream, f1.len()) {
            FrameRead::Ok { payload, .. } => assert_eq!(payload, b"second"),
            other => panic!("expected Ok, got {other:?}"),
        }
        match read_frame(&stream, stream.len()) {
            FrameRead::End => {}
            other => panic!("expected End, got {other:?}"),
        }

        // Chop the second frame mid-payload: torn, valid up to frame 1.
        let torn = &stream[..f1.len() + 6];
        match read_frame(torn, f1.len()) {
            FrameRead::Torn { valid_up_to, .. } => assert_eq!(valid_up_to, f1.len()),
            other => panic!("expected Torn, got {other:?}"),
        }

        // Flip a payload bit: CRC catches it.
        let mut flipped = stream.clone();
        let bit = f1.len() + 9;
        flipped[bit] ^= 0x01;
        match read_frame(&flipped, f1.len()) {
            FrameRead::Torn { reason, .. } => assert!(reason.contains("crc")),
            other => panic!("expected Torn, got {other:?}"),
        }
    }
}
