//! Recovery: newest valid checkpoint + deterministic replay to the WAL tip.
//!
//! There is no redo-log of parameter bytes to apply — the simulation is
//! bitwise-deterministic, so recovery *recomputes*: restore the
//! checkpoint state onto a freshly built world and drive the ordinary
//! `Simulation::step` path once per WAL record past the checkpoint.
//! Every replayed iteration's digests (merged worker set, averaged
//! gradient, post-step parameters) must match the record written by the
//! original run; a mismatch means the data dir does not belong to this
//! config/binary and recovery refuses to continue.  Replay cost is
//! proportional to `tip − checkpoint` — the checkpoint cadence is the
//! knob trading write amplification against recovery time.

use crate::sim::Simulation;
use crate::trace::{ArgValue, TraceHandle, Track};

use super::frame::{Result, StorageError};
use super::wal::{TailStatus, WalRecord};
use super::RunStore;

/// What `recover` is allowed to do to the data dir.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoverMode {
    /// Read-only: report and verify, never write (``mlitb recover --verify``).
    Verify,
    /// Prepare the dir for continued training: a torn WAL tail is
    /// truncated so the writer can reopen it.
    Resume,
}

/// Outcome of a recovery pass.
#[derive(Debug, Clone)]
pub struct RecoveryReport {
    /// Iteration of the checkpoint restored (None: no usable checkpoint,
    /// replay started from a fresh world at iteration 0).
    pub checkpoint_iteration: Option<u64>,
    /// Iterations re-stepped after the restore point.
    pub replayed: u64,
    /// Replayed iterations whose digests matched their WAL record
    /// (always equals `replayed` — a mismatch aborts recovery).
    pub verified: u64,
    /// First iteration the resumed run will execute.
    pub tip: u64,
    /// Description of a torn tail record, if one was found.
    pub torn: Option<String>,
    /// Whether the torn tail was truncated in place (Resume mode only).
    pub repaired: bool,
    /// Non-fatal oddities (skipped corrupt checkpoints, short WAL).
    pub warnings: Vec<String>,
}

impl RecoveryReport {
    /// One-line human summary for CLI output.
    pub fn summary(&self) -> String {
        let base = match self.checkpoint_iteration {
            Some(c) => format!("checkpoint @{c}"),
            None => "no checkpoint (fresh world)".to_string(),
        };
        let mut s = format!(
            "{base}, replayed {} iteration(s), verified {}, tip {}",
            self.replayed, self.verified, self.tip
        );
        if let Some(torn) = &self.torn {
            s.push_str(&format!(
                ", torn tail {} ({torn})",
                if self.repaired { "truncated" } else { "found" }
            ));
        }
        s
    }
}

fn mismatch(field: &str, replayed: &WalRecord, logged: &WalRecord) -> StorageError {
    StorageError::Corrupt(format!(
        "replay diverged at iteration {}: {field} differs (replayed {replayed:?}, log {logged:?}) \
         — the data dir was not produced by this config/binary",
        logged.iteration
    ))
}

fn verify_record(replayed: &WalRecord, logged: &WalRecord) -> Result<()> {
    if replayed.iteration != logged.iteration {
        return Err(mismatch("iteration", replayed, logged));
    }
    if replayed.t_virtual_ms.to_bits() != logged.t_virtual_ms.to_bits() {
        return Err(mismatch("t_virtual_ms", replayed, logged));
    }
    if replayed.seed != logged.seed {
        return Err(mismatch("seed", replayed, logged));
    }
    if replayed.workers != logged.workers {
        return Err(mismatch("workers", replayed, logged));
    }
    if replayed.worker_set_digest != logged.worker_set_digest {
        return Err(mismatch("worker_set_digest", replayed, logged));
    }
    if replayed.stepped != logged.stepped {
        return Err(mismatch("stepped", replayed, logged));
    }
    if replayed.grad_digest != logged.grad_digest {
        return Err(mismatch("grad_digest", replayed, logged));
    }
    if replayed.params_digest != logged.params_digest {
        return Err(mismatch("params_digest", replayed, logged));
    }
    Ok(())
}

/// Recover `sim` (freshly built from the same `(SimConfig, ModelSpec)`
/// the data dir was written under) to the WAL tip.  On return the
/// simulation sits at iteration `report.tip` with digest records enabled;
/// a Resume caller attaches `store.open_wal_for_append()` and keeps
/// stepping.  `trace`/`pid` feed the recovery `replay` span (pass
/// `TraceHandle::off()` when not tracing).
pub fn recover(
    sim: &mut Simulation<'_>,
    store: &RunStore,
    mode: RecoverMode,
    trace: &TraceHandle,
    pid: u32,
) -> Result<RecoveryReport> {
    let (records, tail) = store.read_wal()?;
    let mut report = RecoveryReport {
        checkpoint_iteration: None,
        replayed: 0,
        verified: 0,
        tip: 0,
        torn: None,
        repaired: false,
        warnings: Vec::new(),
    };
    if let TailStatus::Truncated {
        valid_bytes,
        dropped_bytes,
        reason,
    } = &tail
    {
        report.torn = Some(format!(
            "{reason} ({dropped_bytes} bytes dropped after offset {valid_bytes})"
        ));
        if mode == RecoverMode::Resume {
            store.repair_wal_tail()?;
            report.repaired = true;
        }
    }
    // The log must be one contiguous run of iterations starting at 0 —
    // anything else is not a WAL this plane wrote.
    for (i, rec) in records.iter().enumerate() {
        if rec.iteration != i as u64 {
            return Err(StorageError::Corrupt(format!(
                "wal record {} carries iteration {} (log is not contiguous)",
                i, rec.iteration
            )));
        }
    }

    let (ckpt, warnings) = store.load_latest_checkpoint()?;
    report.warnings = warnings;
    let replay_from = match ckpt {
        Some(st) => {
            let c = st.master.iteration;
            report.checkpoint_iteration = Some(c);
            sim.restore_state(st);
            if c as usize > records.len() {
                report.warnings.push(format!(
                    "wal ends at iteration {} but the checkpoint is at {c}; \
                     nothing to replay (log lost after the last sync)",
                    records.len()
                ));
            }
            c
        }
        // No checkpoint: a fresh world at iteration 0 *is* the restore
        // point, so an empty or missing checkpoint set still recovers by
        // replaying the whole log.
        None => 0,
    };

    sim.master_mut().enable_wal_digests(store.identity().seed);
    let t_replay_start = sim.master().now_ms();
    for logged in records.iter().skip(replay_from as usize) {
        sim.step().map_err(|e| {
            StorageError::Corrupt(format!(
                "replay failed at iteration {}: {e}",
                logged.iteration
            ))
        })?;
        let replayed = *sim.master().last_wal_record().ok_or_else(|| {
            StorageError::Corrupt("replay produced no wal record".into())
        })?;
        verify_record(&replayed, logged)?;
        report.replayed += 1;
        report.verified += 1;
    }
    report.tip = (records.len() as u64).max(replay_from);
    if report.replayed > 0 && trace.is_on() {
        trace.span(
            Track::master(pid),
            "storage",
            "replay",
            t_replay_start,
            sim.master().now_ms(),
            &[
                ("from", ArgValue::U64(replay_from)),
                ("replayed", ArgValue::U64(report.replayed)),
                ("verified", ArgValue::U64(report.verified)),
            ],
        );
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::DeviceClass;
    use crate::model::{ModelSpec, TensorSpec};
    use crate::runtime::ModeledCompute;
    use crate::sim::SimConfig;
    use std::path::PathBuf;

    fn toy_spec() -> ModelSpec {
        ModelSpec {
            name: "toy".into(),
            param_count: 8,
            batch_size: 16,
            micro_batches: vec![16],
            input: vec![28, 28, 1],
            classes: 10,
            tensors: vec![TensorSpec {
                name: "w".into(),
                shape: vec![8],
                offset: 0,
                size: 8,
                fan_in: 4,
            }],
            artifacts: Default::default(),
        }
    }

    fn toy_cfg(spec: &ModelSpec, seed: u64) -> SimConfig {
        let mut cfg = SimConfig::paper_scaling(3, spec);
        cfg.fleet = vec![DeviceClass::Mobile, DeviceClass::Laptop, DeviceClass::Mobile];
        cfg.train_size = 300;
        cfg.test_size = 32;
        cfg.iterations = 10;
        cfg.master.capacity = 100;
        cfg.seed = seed;
        cfg
    }

    fn test_dir(tag: &str) -> PathBuf {
        let d =
            std::env::temp_dir().join(format!("mlitb-recover-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// Run `iterations` durably: WAL every iteration, checkpoint at the
    /// given cadence; returns the final param bits.
    fn run_durable(
        dir: &std::path::Path,
        seed: u64,
        iterations: u64,
        checkpoint_every: u64,
    ) -> Vec<u32> {
        let spec = toy_spec();
        let cfg = toy_cfg(&spec, seed);
        let store = RunStore::open_for_config(dir, &cfg).unwrap();
        let mut compute = ModeledCompute { param_count: 8 };
        let mut sim = Simulation::new(cfg, spec, &mut compute);
        let wal = store.open_wal_for_append().unwrap();
        sim.master_mut().attach_wal(wal, seed);
        for it in 0..iterations {
            sim.step().unwrap();
            if checkpoint_every > 0 && (it + 1) % checkpoint_every == 0 {
                sim.master_mut().wal_mut().unwrap().sync().unwrap();
                store.write_checkpoint(&sim.capture_state()).unwrap();
            }
        }
        sim.master_mut().wal_mut().unwrap().sync().unwrap();
        sim.master().params().iter().map(|p| p.to_bits()).collect()
    }

    fn recover_and_finish(dir: &std::path::Path, seed: u64, total: u64) -> (RecoveryReport, Vec<u32>) {
        let spec = toy_spec();
        let cfg = toy_cfg(&spec, seed);
        let store = RunStore::open_for_config(dir, &cfg).unwrap();
        let mut compute = ModeledCompute { param_count: 8 };
        let mut sim = Simulation::new(cfg, spec, &mut compute);
        let report = recover(
            &mut sim,
            &store,
            RecoverMode::Resume,
            &TraceHandle::off(),
            0,
        )
        .unwrap();
        let wal = store.open_wal_for_append().unwrap();
        sim.master_mut().attach_wal(wal, seed);
        for _ in report.tip..total {
            sim.step().unwrap();
        }
        sim.master_mut().wal_mut().unwrap().sync().unwrap();
        (
            report,
            sim.master().params().iter().map(|p| p.to_bits()).collect(),
        )
    }

    #[test]
    fn kill_and_recover_is_bitwise_identical() {
        // Reference: 10 uninterrupted iterations.  Crashed run: killed
        // after 7, checkpoint cadence 3 (checkpoints at 3 and 6, so one
        // replayed iteration).  Two seeds × two cadences, one of which
        // does not divide the kill point.
        for seed in [11u64, 29] {
            let ref_dir = test_dir(&format!("ref-{seed}"));
            let reference = run_durable(&ref_dir, seed, 10, 4);
            for cadence in [3u64, 4] {
                let dir = test_dir(&format!("kill-{seed}-{cadence}"));
                let _killed = run_durable(&dir, seed, 7, cadence);
                let (report, resumed) = recover_and_finish(&dir, seed, 10);
                assert_eq!(report.tip, 7);
                assert_eq!(
                    report.checkpoint_iteration,
                    Some(7 / cadence * cadence),
                    "cadence {cadence}"
                );
                assert_eq!(report.replayed, 7 - 7 / cadence * cadence);
                assert_eq!(report.verified, report.replayed);
                assert_eq!(resumed, reference, "seed {seed} cadence {cadence}");
                let _ = std::fs::remove_dir_all(&dir);
            }
            let _ = std::fs::remove_dir_all(&ref_dir);
        }
    }

    #[test]
    fn missing_wal_recovers_to_fresh_world() {
        let dir = test_dir("fresh");
        let spec = toy_spec();
        let cfg = toy_cfg(&spec, 5);
        let store = RunStore::open_for_config(&dir, &cfg).unwrap();
        let mut compute = ModeledCompute { param_count: 8 };
        let mut sim = Simulation::new(cfg, spec, &mut compute);
        let report = recover(
            &mut sim,
            &store,
            RecoverMode::Verify,
            &TraceHandle::off(),
            0,
        )
        .unwrap();
        assert_eq!(report.tip, 0);
        assert_eq!(report.replayed, 0);
        assert!(report.checkpoint_iteration.is_none());
        assert_eq!(sim.master().iteration(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checkpoint_with_no_tail_replays_nothing() {
        let dir = test_dir("no-tail");
        run_durable(&dir, 7, 6, 6); // checkpoint exactly at the end
        let (report, _) = recover_and_finish(&dir, 7, 6);
        assert_eq!(report.checkpoint_iteration, Some(6));
        assert_eq!(report.replayed, 0);
        assert_eq!(report.tip, 6);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_truncated_and_replay_continues() {
        let dir = test_dir("torn");
        let reference = run_durable(&test_dir("torn-ref"), 13, 10, 3);
        run_durable(&dir, 13, 7, 3);
        // Tear the last record: drop its final 3 bytes.
        let wal = dir.join(super::super::WAL_FILE);
        let bytes = std::fs::read(&wal).unwrap();
        std::fs::write(&wal, &bytes[..bytes.len() - 3]).unwrap();
        let (report, resumed) = recover_and_finish(&dir, 13, 10);
        assert!(report.torn.is_some());
        assert!(report.repaired);
        // Record 6 was torn away: tip falls back to 6, the resumed run
        // re-executes 6..10 and still lands bitwise on the reference.
        assert_eq!(report.tip, 6);
        assert_eq!(resumed, reference);
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&test_dir("torn-ref"));
    }

    #[test]
    fn verify_mode_does_not_touch_the_torn_tail() {
        let dir = test_dir("verify-ro");
        run_durable(&dir, 17, 5, 2);
        let wal = dir.join(super::super::WAL_FILE);
        let bytes = std::fs::read(&wal).unwrap();
        std::fs::write(&wal, &bytes[..bytes.len() - 2]).unwrap();
        let len_before = std::fs::metadata(&wal).unwrap().len();

        let spec = toy_spec();
        let cfg = toy_cfg(&spec, 17);
        let store = RunStore::open_for_config(&dir, &cfg).unwrap();
        let mut compute = ModeledCompute { param_count: 8 };
        let mut sim = Simulation::new(cfg, spec, &mut compute);
        let report = recover(
            &mut sim,
            &store,
            RecoverMode::Verify,
            &TraceHandle::off(),
            0,
        )
        .unwrap();
        assert!(report.torn.is_some());
        assert!(!report.repaired);
        assert_eq!(std::fs::metadata(&wal).unwrap().len(), len_before);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn foreign_data_dir_is_refused() {
        let dir = test_dir("foreign");
        run_durable(&dir, 3, 4, 2);
        // Same dir, different seed → different identity.
        let spec = toy_spec();
        let cfg = toy_cfg(&spec, 4);
        let store = RunStore::open_for_config(&dir, &cfg).unwrap();
        let mut compute = ModeledCompute { param_count: 8 };
        let mut sim = Simulation::new(cfg, spec, &mut compute);
        let err = recover(
            &mut sim,
            &store,
            RecoverMode::Verify,
            &TraceHandle::off(),
            0,
        )
        .unwrap_err();
        assert!(err.to_string().contains("different run"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn replay_emits_storage_span() {
        let dir = test_dir("span");
        run_durable(&dir, 21, 5, 2);
        let spec = toy_spec();
        let cfg = toy_cfg(&spec, 21);
        let store = RunStore::open_for_config(&dir, &cfg).unwrap();
        let mut compute = ModeledCompute { param_count: 8 };
        let mut sim = Simulation::new(cfg, spec, &mut compute);
        let trace = TraceHandle::recording();
        let report = recover(&mut sim, &store, RecoverMode::Resume, &trace, 2).unwrap();
        assert_eq!(report.replayed, 1);
        assert!(trace
            .snapshot()
            .iter()
            .any(|e| e.name == "replay" && e.track == Track::master(2)));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
