//! Durable state plane: iteration WAL + checkpoint/replay + registry store.
//!
//! The paper's master keeps all project state in memory; a crashed master
//! loses the run.  This plane makes the simulated master durable without
//! touching the hot path:
//!
//! - [`wal`] — an append-only iteration log.  `Master::finish_iteration`
//!   appends one ~70-byte record per iteration (virtual clock, merged
//!   worker set, gradient/parameter digests) through a buffered writer;
//!   no fsync per record.
//! - [`checkpoint`] — periodic full snapshots of the deterministic
//!   training state (parameters, optimizer accumulators, allocator,
//!   latency estimates, per-client state, sim RNG), CRC-framed and
//!   committed by atomic rename.  The WAL is fsynced only at these
//!   boundaries.
//! - [`recover`] — load the newest valid checkpoint and *recompute* the
//!   iterations after it through the ordinary `Simulation::step` path,
//!   verifying each replayed iteration against its WAL record.  Because
//!   the simulation is bitwise-deterministic, replay reproduces the
//!   pre-crash parameters exactly; a torn tail record is truncated (with
//!   a report), never trusted.
//! - [`registry_store`] — segment-file persistence for the serving
//!   plane's `SnapshotRegistry`, so restarts warm with the active
//!   version, staged candidates, and rollback history intact.
//!
//! Everything here is deterministic given the directory contents: ordered
//! iteration only (`BTreeMap`), no wall-clock reads, and all integers
//! little-endian on disk.

pub mod checkpoint;
mod frame;
pub mod recover;
pub mod registry_store;
pub mod wal;

use std::path::{Path, PathBuf};

use crate::sim::{ChurnEvent, SimConfig, SimState};

pub use checkpoint::{checkpoint_iterations, load_latest_checkpoint, read_checkpoint};
pub use frame::{
    crc32, digest_f32s, fnv1a64, ByteReader, ByteWriter, Fnv64, Result, StorageError,
};
pub use recover::{recover, RecoverMode, RecoveryReport};
pub use wal::{
    read_wal, repair_tail, wal_path, RunIdentity, TailStatus, WalRecord, WalWriter, WAL_FILE,
};

/// Digest of the simulation config fields that determine the run's
/// trajectory.  Stamped into the WAL header and every checkpoint so a
/// data dir can never be resumed under a different world: same digest ⇒
/// `Simulation::new` rebuilds the identical corpus, fleet and schedule.
pub fn config_digest(cfg: &SimConfig) -> u64 {
    let mut w = ByteWriter::new();
    w.put_str(&cfg.model);
    w.put_u32(cfg.fleet.len() as u32);
    for class in &cfg.fleet {
        w.put_str(class.name());
    }
    w.put_u64(cfg.train_size as u64);
    w.put_u64(cfg.test_size as u64);
    w.put_u64(cfg.iterations);
    w.put_u64(cfg.track_every);
    w.put_f64(cfg.power_scale);
    w.put_u64(cfg.cache_budget);
    w.put_u64(cfg.seed);
    let m = &cfg.master;
    w.put_u64(m.param_count as u64);
    w.put_f64(m.iter_duration_s);
    w.put_str(&m.optimizer_name());
    w.put_f32(m.learning_rate);
    w.put_u64(m.capacity as u64);
    w.put_f64(m.shed_threshold);
    match m.policy {
        crate::coordinator::ReducePolicy::Sync => w.put_u8(0),
        crate::coordinator::ReducePolicy::Async => w.put_u8(1),
        crate::coordinator::ReducePolicy::PartialSync { keep_fraction } => {
            w.put_u8(2);
            w.put_f64(keep_fraction);
        }
    }
    let mm = &m.master_model;
    w.put_f64(mm.ingest_bandwidth_bytes_per_ms);
    w.put_f64(mm.per_msg_overhead_ms);
    w.put_f64(mm.merge_ns_per_param);
    w.put_u64(mm.processes as u64);
    w.put_str(&mm.reduce_mode.name());
    w.put_f64(mm.fanin_ns_per_shard);
    w.put_u64(mm.congestion_bytes);
    w.put_u32(cfg.churn.len() as u32);
    for (iter, events) in &cfg.churn {
        w.put_u64(*iter);
        w.put_u32(events.len() as u32);
        for ev in events {
            match ev {
                ChurnEvent::Join(class) => {
                    w.put_u8(0);
                    w.put_str(class.name());
                }
                ChurnEvent::Leave(worker) => {
                    w.put_u8(1);
                    w.put_u64(*worker);
                }
            }
        }
    }
    fnv1a64(&w.finish())
}

/// One training run's data directory: `wal.log` + `ckpt-*.bin` files,
/// all stamped with the run's [`RunIdentity`].
#[derive(Debug, Clone)]
pub struct RunStore {
    dir: PathBuf,
    identity: RunIdentity,
}

impl RunStore {
    /// Open (creating if needed) the data dir for a run with this
    /// identity.  Existing files are validated lazily, at read time.
    pub fn open(dir: &Path, identity: RunIdentity) -> Result<Self> {
        std::fs::create_dir_all(dir)?;
        Ok(Self {
            dir: dir.to_path_buf(),
            identity,
        })
    }

    /// Convenience: identity derived from the config.
    pub fn open_for_config(dir: &Path, cfg: &SimConfig) -> Result<Self> {
        Self::open(
            dir,
            RunIdentity {
                seed: cfg.seed,
                config_digest: config_digest(cfg),
            },
        )
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn identity(&self) -> RunIdentity {
        self.identity
    }

    pub fn wal_path(&self) -> PathBuf {
        wal::wal_path(&self.dir)
    }

    /// Open the WAL for appending (creates it with this run's header).
    /// Refuses a foreign identity or a torn tail — repair first.
    pub fn open_wal_for_append(&self) -> Result<WalWriter> {
        WalWriter::open(&self.wal_path(), self.identity)
    }

    /// All valid WAL records plus the tail status.  A missing WAL reads
    /// as empty-and-clean (a run that never started logging).
    pub fn read_wal(&self) -> Result<(Vec<WalRecord>, TailStatus)> {
        let path = self.wal_path();
        if !path.exists() {
            return Ok((Vec::new(), TailStatus::Clean));
        }
        let (identity, records, tail) = wal::read_wal(&path)?;
        if identity != self.identity {
            return Err(StorageError::Corrupt(format!(
                "{} belongs to a different run (seed {} config {:#x}; this run is seed {} config {:#x})",
                path.display(),
                identity.seed,
                identity.config_digest,
                self.identity.seed,
                self.identity.config_digest
            )));
        }
        Ok((records, tail))
    }

    /// Truncate a torn WAL tail in place (no-op when the WAL is absent).
    pub fn repair_wal_tail(&self) -> Result<()> {
        let path = self.wal_path();
        if path.exists() {
            repair_tail(&path)?;
        }
        Ok(())
    }

    pub fn write_checkpoint(&self, st: &SimState) -> Result<PathBuf> {
        checkpoint::write_checkpoint(&self.dir, self.identity, st)
    }

    pub fn load_latest_checkpoint(&self) -> Result<(Option<SimState>, Vec<String>)> {
        checkpoint::load_latest_checkpoint(&self.dir, self.identity)
    }

    pub fn checkpoint_iterations(&self) -> Result<Vec<u64>> {
        checkpoint::checkpoint_iterations(&self.dir)
    }
}
