//! The iteration write-ahead log: one length-prefixed, CRC-framed binary
//! record per master iteration.
//!
//! The WAL does not store gradients — the simulation is deterministic
//! given (config, seed), so recovery *recomputes* iterations from the
//! last checkpoint through the normal reduce/step path and uses the
//! logged digests to verify each replayed iteration is bitwise-identical
//! to the one that originally ran. The log is therefore tiny (~70 bytes
//! per iteration) and append cost stays off the hot path: records go
//! through a `BufWriter` with **no per-record sync**; the file is synced
//! only at checkpoint boundaries (`WalWriter::sync`), where losing the
//! buffered tail costs at most `checkpoint_every` iterations of replay.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use super::frame::{frame, read_frame, ByteReader, ByteWriter, FrameRead, Result, StorageError};

/// File name of the log inside a run's data dir.
pub const WAL_FILE: &str = "wal.log";

const WAL_MAGIC: &[u8; 4] = b"MLWL";
const WAL_VERSION: u32 = 1;
/// magic + version + seed + config digest.
const HEADER_LEN: usize = 4 + 4 + 8 + 8;

/// Identity of the run a WAL (or checkpoint) belongs to: the seed and a
/// digest of the simulation config. Recovery refuses to replay a log
/// against a differently-configured simulation — the replay would
/// silently diverge instead of failing loudly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RunIdentity {
    pub seed: u64,
    pub config_digest: u64,
}

/// One iteration's log entry. Digests are FNV-1a 64 over little-endian
/// bytes; `grad_digest` covers the merged weighted-average gradient the
/// optimizer consumed (`stepped == false` means no work arrived and the
/// digest field is meaningless), `params_digest` covers the parameter
/// vector *after* the optimizer step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WalRecord {
    pub iteration: u64,
    /// Virtual clock at the end of the iteration (ms).
    pub t_virtual_ms: f64,
    pub seed: u64,
    /// Number of submissions merged into the reduce step.
    pub workers: u32,
    /// Digest over the merged worker ids, in merge order.
    pub worker_set_digest: u64,
    /// Whether the optimizer stepped this iteration.
    pub stepped: bool,
    pub grad_digest: u64,
    pub params_digest: u64,
}

impl WalRecord {
    fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_u64(self.iteration);
        w.put_f64(self.t_virtual_ms);
        w.put_u64(self.seed);
        w.put_u32(self.workers);
        w.put_u64(self.worker_set_digest);
        w.put_u8(self.stepped as u8);
        w.put_u64(self.grad_digest);
        w.put_u64(self.params_digest);
        w.finish()
    }

    fn decode(payload: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(payload);
        let rec = Self {
            iteration: r.get_u64()?,
            t_virtual_ms: r.get_f64()?,
            seed: r.get_u64()?,
            workers: r.get_u32()?,
            worker_set_digest: r.get_u64()?,
            stepped: r.get_u8()? != 0,
            grad_digest: r.get_u64()?,
            params_digest: r.get_u64()?,
        };
        r.expect_end()?;
        Ok(rec)
    }
}

/// What the reader found at the end of the log.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TailStatus {
    Clean,
    /// The final record was torn (partial write or CRC mismatch): the
    /// log is valid up to `valid_bytes`; `dropped_bytes` were discarded.
    /// Recovery truncates to `valid_bytes` and replays from there — a
    /// crash mid-append costs one iteration of replay, never the run.
    Truncated {
        valid_bytes: u64,
        dropped_bytes: u64,
        reason: String,
    },
}

fn encode_header(id: RunIdentity) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN);
    out.extend_from_slice(WAL_MAGIC);
    out.extend_from_slice(&WAL_VERSION.to_le_bytes());
    out.extend_from_slice(&id.seed.to_le_bytes());
    out.extend_from_slice(&id.config_digest.to_le_bytes());
    out
}

fn decode_header(bytes: &[u8]) -> Result<RunIdentity> {
    if bytes.len() < HEADER_LEN {
        return Err(StorageError::Corrupt(format!(
            "wal header truncated: {} of {HEADER_LEN} bytes",
            bytes.len()
        )));
    }
    if &bytes[0..4] != WAL_MAGIC {
        return Err(StorageError::Corrupt("bad wal magic".into()));
    }
    let version = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]);
    if version != WAL_VERSION {
        return Err(StorageError::Corrupt(format!(
            "unsupported wal version {version}"
        )));
    }
    let mut r = ByteReader::new(&bytes[8..HEADER_LEN]);
    Ok(RunIdentity {
        seed: r.get_u64()?,
        config_digest: r.get_u64()?,
    })
}

/// Buffered appender. Creating one on a fresh file writes the header; on
/// an existing file the header is verified against `identity` and appends
/// continue at the end (the caller repairs a torn tail first — see
/// [`read_wal`] / [`repair_tail`]).
#[derive(Debug)]
pub struct WalWriter {
    out: BufWriter<File>,
    bytes_appended: u64,
    records_appended: u64,
    records_since_sync: u64,
}

impl WalWriter {
    pub fn open(path: &Path, identity: RunIdentity) -> Result<Self> {
        let exists = path.exists();
        if exists {
            // Verify we are appending to the same run's log, and refuse
            // to append after a torn tail (repair_tail first).
            let (found, _, tail) = read_wal(path)?;
            if let TailStatus::Truncated { reason, .. } = tail {
                return Err(StorageError::Corrupt(format!(
                    "wal at {} has a torn tail ({reason}); run recovery to repair it first",
                    path.display()
                )));
            }
            if found != identity {
                return Err(StorageError::Corrupt(format!(
                    "wal at {} belongs to a different run (seed {} config {:#018x}, expected seed {} config {:#018x})",
                    path.display(),
                    found.seed,
                    found.config_digest,
                    identity.seed,
                    identity.config_digest,
                )));
            }
        }
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        let mut w = Self {
            out: BufWriter::new(file),
            bytes_appended: 0,
            records_appended: 0,
            records_since_sync: 0,
        };
        if !exists {
            let header = encode_header(identity);
            w.out.write_all(&header)?;
            w.bytes_appended += header.len() as u64;
        }
        Ok(w)
    }

    /// Append one record. Buffered only — no flush, no sync; the bytes
    /// reach the page cache when the `BufWriter` fills or at the next
    /// checkpoint-boundary [`sync`](Self::sync).
    pub fn append(&mut self, rec: &WalRecord) -> Result<()> {
        let framed = frame(&rec.encode());
        self.out.write_all(&framed)?;
        self.bytes_appended += framed.len() as u64;
        self.records_appended += 1;
        self.records_since_sync += 1;
        Ok(())
    }

    /// Flush and fsync — called at checkpoint boundaries only, so the
    /// sync cost amortizes over `checkpoint_every` iterations.
    pub fn sync(&mut self) -> Result<()> {
        self.out.flush()?;
        self.out.get_ref().sync_data()?;
        self.records_since_sync = 0;
        Ok(())
    }

    /// Total bytes this writer has appended (header included on create).
    pub fn bytes_appended(&self) -> u64 {
        self.bytes_appended
    }

    pub fn records_appended(&self) -> u64 {
        self.records_appended
    }

    /// Records appended since the last checkpoint-boundary sync — the
    /// replay distance a crash right now would cost.
    pub fn records_since_sync(&self) -> u64 {
        self.records_since_sync
    }
}

/// Read a whole WAL: header identity, every valid record, and the tail
/// status. A torn tail is *reported*, not repaired — call [`repair_tail`]
/// to truncate before reopening for append.
pub fn read_wal(path: &Path) -> Result<(RunIdentity, Vec<WalRecord>, TailStatus)> {
    let mut bytes = Vec::new();
    File::open(path)?.read_to_end(&mut bytes)?;
    let identity = decode_header(&bytes)?;
    let mut records = Vec::new();
    let mut offset = HEADER_LEN;
    loop {
        match read_frame(&bytes, offset) {
            FrameRead::End => return Ok((identity, records, TailStatus::Clean)),
            FrameRead::Ok { payload, consumed } => {
                records.push(WalRecord::decode(payload)?);
                offset += consumed;
            }
            FrameRead::Torn {
                valid_up_to,
                reason,
            } => {
                return Ok((
                    identity,
                    records,
                    TailStatus::Truncated {
                        valid_bytes: valid_up_to as u64,
                        dropped_bytes: (bytes.len() - valid_up_to) as u64,
                        reason,
                    },
                ));
            }
        }
    }
}

/// Truncate a torn tail in place (no-op on a clean log). Returns the
/// tail status that was found, so callers can surface the warning.
pub fn repair_tail(path: &Path) -> Result<TailStatus> {
    let (_, _, tail) = read_wal(path)?;
    if let TailStatus::Truncated { valid_bytes, .. } = &tail {
        let file = OpenOptions::new().write(true).open(path)?;
        file.set_len(*valid_bytes)?;
        file.sync_data()?;
    }
    Ok(tail)
}

/// Path of the WAL inside a data dir.
pub fn wal_path(dir: &Path) -> PathBuf {
    dir.join(WAL_FILE)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "mlitb-wal-{}-{tag}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn rec(i: u64) -> WalRecord {
        WalRecord {
            iteration: i,
            t_virtual_ms: i as f64 * 4000.0,
            seed: 7,
            workers: 4,
            worker_set_digest: 0x1234 + i,
            stepped: true,
            grad_digest: 0xAAAA + i,
            params_digest: 0xBBBB + i,
        }
    }

    const ID: RunIdentity = RunIdentity {
        seed: 7,
        config_digest: 0xC0FFEE,
    };

    #[test]
    fn append_read_roundtrip() {
        let dir = tmp("roundtrip");
        let path = wal_path(&dir);
        let mut w = WalWriter::open(&path, ID).unwrap();
        for i in 0..5 {
            w.append(&rec(i)).unwrap();
        }
        assert_eq!(w.records_appended(), 5);
        assert_eq!(w.records_since_sync(), 5);
        w.sync().unwrap();
        assert_eq!(w.records_since_sync(), 0);
        drop(w);

        let (id, records, tail) = read_wal(&path).unwrap();
        assert_eq!(id, ID);
        assert_eq!(tail, TailStatus::Clean);
        assert_eq!(records.len(), 5);
        assert_eq!(records[3], rec(3));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn reopen_appends_and_rejects_wrong_identity() {
        let dir = tmp("reopen");
        let path = wal_path(&dir);
        let mut w = WalWriter::open(&path, ID).unwrap();
        w.append(&rec(0)).unwrap();
        w.sync().unwrap();
        drop(w);

        let mut w2 = WalWriter::open(&path, ID).unwrap();
        w2.append(&rec(1)).unwrap();
        w2.sync().unwrap();
        drop(w2);
        let (_, records, tail) = read_wal(&path).unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(tail, TailStatus::Clean);

        let other = RunIdentity {
            seed: 8,
            ..ID
        };
        assert!(WalWriter::open(&path, other).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_tail_is_reported_and_repairable() {
        let dir = tmp("torn");
        let path = wal_path(&dir);
        let mut w = WalWriter::open(&path, ID).unwrap();
        for i in 0..3 {
            w.append(&rec(i)).unwrap();
        }
        w.sync().unwrap();
        drop(w);

        // Simulate a crash mid-append: chop 5 bytes off the last record.
        let len = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 5).unwrap();
        drop(f);

        let (_, records, tail) = read_wal(&path).unwrap();
        assert_eq!(records.len(), 2);
        let valid = match &tail {
            TailStatus::Truncated { valid_bytes, dropped_bytes, .. } => {
                assert!(*dropped_bytes > 0);
                *valid_bytes
            }
            TailStatus::Clean => panic!("expected torn tail"),
        };

        let repaired = repair_tail(&path).unwrap();
        assert_eq!(repaired, tail);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), valid);
        // After repair the log is clean and appendable again.
        let (_, records, tail) = read_wal(&path).unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(tail, TailStatus::Clean);
        let mut w = WalWriter::open(&path, ID).unwrap();
        w.append(&rec(2)).unwrap();
        w.sync().unwrap();
        drop(w);
        let (_, records, _) = read_wal(&path).unwrap();
        assert_eq!(records.len(), 3);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn crc_flip_inside_tail_record_truncates_it() {
        let dir = tmp("crcflip");
        let path = wal_path(&dir);
        let mut w = WalWriter::open(&path, ID).unwrap();
        w.append(&rec(0)).unwrap();
        w.append(&rec(1)).unwrap();
        w.sync().unwrap();
        drop(w);

        // Flip one payload byte in the last record.
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 3] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();

        let (_, records, tail) = read_wal(&path).unwrap();
        assert_eq!(records.len(), 1);
        match tail {
            TailStatus::Truncated { reason, .. } => assert!(reason.contains("crc")),
            TailStatus::Clean => panic!("expected crc-torn tail"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
