//! Segment-file persistence for the serving plane's snapshot registry.
//!
//! Layout of one registry's directory:
//!
//! ```text
//! MANIFEST          MLMF | version:u32 | frame(registry state sans params)
//! seg-0000000001.bin   MLSG | frame(version + parameter vector)
//! seg-0000000004.bin   ...
//! ```
//!
//! Segments are **immutable**: a version's parameters never change, so a
//! segment is written once (atomically) and only ever deleted.  The
//! manifest is the commit point — it is replaced by rename after the
//! segments it references exist, and every load cross-checks each
//! segment's CRC, version and parameter digest against its manifest row.
//! [`save`] sweeps segment files the manifest no longer references, so
//! retention GC folds into persistence: drop versions in memory, save,
//! and their bytes are gone — no orphaned segments.

use std::collections::BTreeSet;
use std::fs::{self, File};
use std::io::Write as _;
use std::path::Path;
use std::sync::Arc;

use crate::model::ModelSpec;
use crate::serve::{ProjectId, RegistryState, SnapshotRegistry, SnapshotRow};

use super::frame::{
    digest_f32s, frame, read_frame, ByteReader, ByteWriter, FrameRead, Result, StorageError,
};

pub const MANIFEST_FILE: &str = "MANIFEST";
const MANIFEST_MAGIC: &[u8; 4] = b"MLMF";
const SEGMENT_MAGIC: &[u8; 4] = b"MLSG";
const FORMAT_VERSION: u32 = 1;

/// File name of the segment holding `version`'s parameters.
pub fn segment_file_name(version: u64) -> String {
    format!("seg-{version:010}.bin")
}

fn parse_segment_name(name: &str) -> Option<u64> {
    name.strip_prefix("seg-")
        .and_then(|s| s.strip_suffix(".bin"))
        .and_then(|d| d.parse().ok())
}

fn write_atomic(dir: &Path, name: &str, bytes: &[u8]) -> Result<()> {
    let tmp = dir.join(format!("{name}.tmp"));
    let mut f = File::create(&tmp)?;
    f.write_all(bytes)?;
    f.sync_data()?;
    drop(f);
    fs::rename(&tmp, dir.join(name))?;
    let _ = File::open(dir).and_then(|d| d.sync_all());
    Ok(())
}

fn framed_file(magic: &[u8; 4], payload: &[u8]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(8 + 8 + payload.len());
    bytes.extend_from_slice(magic);
    bytes.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    bytes.extend_from_slice(&frame(payload));
    bytes
}

fn read_framed_file(path: &Path, magic: &[u8; 4]) -> Result<Vec<u8>> {
    let bytes = fs::read(path)?;
    if bytes.len() < 8 || &bytes[..4] != magic {
        return Err(StorageError::Corrupt(format!(
            "{}: bad magic",
            path.display()
        )));
    }
    let version = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]);
    if version != FORMAT_VERSION {
        return Err(StorageError::Corrupt(format!(
            "{}: unsupported format version {version}",
            path.display()
        )));
    }
    match read_frame(&bytes, 8) {
        FrameRead::Ok { payload, consumed } if 8 + consumed == bytes.len() => {
            Ok(payload.to_vec())
        }
        FrameRead::Ok { .. } => Err(StorageError::Corrupt(format!(
            "{}: trailing bytes after frame",
            path.display()
        ))),
        FrameRead::End => Err(StorageError::Corrupt(format!(
            "{}: empty file",
            path.display()
        ))),
        FrameRead::Torn { reason, .. } => Err(StorageError::Corrupt(format!(
            "{}: {reason}",
            path.display()
        ))),
    }
}

fn encode_manifest(st: &RegistryState) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u64(st.next);
    w.put_opt_u64(st.active);
    w.put_u64s(&st.staged);
    w.put_u32(st.rows.len() as u32);
    for row in &st.rows {
        w.put_u64(row.version);
        w.put_str(&row.model);
        w.put_u64(row.iteration);
        w.put_str(&row.notes);
        w.put_f64(row.published_ms);
        w.put_u32(row.params.len() as u32);
        w.put_u64(digest_f32s(&row.params));
    }
    w.finish()
}

/// A manifest row before its segment has been read back.
struct ManifestRow {
    version: u64,
    model: String,
    iteration: u64,
    notes: String,
    published_ms: f64,
    param_count: u32,
    params_digest: u64,
}

fn decode_manifest(payload: &[u8]) -> Result<(u64, Option<u64>, Vec<u64>, Vec<ManifestRow>)> {
    let mut r = ByteReader::new(payload);
    let next = r.get_u64()?;
    let active = r.get_opt_u64()?;
    let staged = r.get_u64s()?;
    let n = r.get_u32()?;
    let mut rows = Vec::with_capacity(n as usize);
    for _ in 0..n {
        rows.push(ManifestRow {
            version: r.get_u64()?,
            model: r.get_str()?,
            iteration: r.get_u64()?,
            notes: r.get_str()?,
            published_ms: r.get_f64()?,
            param_count: r.get_u32()?,
            params_digest: r.get_u64()?,
        });
    }
    r.expect_end()?;
    Ok((next, active, staged, rows))
}

/// Persist a registry into `dir`: write any missing segments, commit the
/// manifest atomically, then sweep segments the manifest no longer
/// references.  Idempotent, and safe to call mid-traffic — reader pins
/// are runtime state and are not persisted.
pub fn save(dir: &Path, reg: &SnapshotRegistry) -> Result<()> {
    fs::create_dir_all(dir)?;
    let st = reg.export_state();
    for row in &st.rows {
        let name = segment_file_name(row.version);
        if dir.join(&name).exists() {
            continue; // segments are immutable per version
        }
        let mut w = ByteWriter::new();
        w.put_u64(row.version);
        w.put_f32s(&row.params);
        write_atomic(dir, &name, &framed_file(SEGMENT_MAGIC, &w.finish()))?;
    }
    write_atomic(
        dir,
        MANIFEST_FILE,
        &framed_file(MANIFEST_MAGIC, &encode_manifest(&st)),
    )?;
    sweep_orphans(dir, &st.rows.iter().map(|r| r.version).collect())?;
    Ok(())
}

/// Determinism audit: `read_dir` order is OS-dependent, but deletion is
/// a per-file predicate (name not in `keep`) — the surviving set is the
/// same whatever order the entries arrive in.
fn sweep_orphans(dir: &Path, keep: &BTreeSet<u64>) -> Result<()> {
    for entry in fs::read_dir(dir)? {
        let name = entry?.file_name();
        let Some(name) = name.to_str() else { continue };
        let is_stale_tmp = name.ends_with(".tmp");
        let is_orphan_segment =
            parse_segment_name(name).is_some_and(|v| !keep.contains(&v));
        if is_stale_tmp || is_orphan_segment {
            fs::remove_file(dir.join(name))?;
        }
    }
    Ok(())
}

/// Load a registry persisted by [`save`].  `Ok(None)` when `dir` has no
/// manifest (nothing was ever persisted); `Err` when the manifest exists
/// but cannot be honored — including a manifest row whose segment file
/// was deleted out from under it.
pub fn load(dir: &Path, project: ProjectId, spec: &ModelSpec) -> Result<Option<SnapshotRegistry>> {
    let manifest_path = dir.join(MANIFEST_FILE);
    if !manifest_path.exists() {
        return Ok(None);
    }
    let (next, active, staged, manifest_rows) =
        decode_manifest(&read_framed_file(&manifest_path, MANIFEST_MAGIC)?)?;
    let mut rows = Vec::with_capacity(manifest_rows.len());
    for m in manifest_rows {
        let seg_path = dir.join(segment_file_name(m.version));
        if !seg_path.exists() {
            return Err(StorageError::Corrupt(format!(
                "manifest references v{} but {} is missing",
                m.version,
                seg_path.display()
            )));
        }
        let payload = read_framed_file(&seg_path, SEGMENT_MAGIC)?;
        let mut r = ByteReader::new(&payload);
        let seg_version = r.get_u64()?;
        let params = r.get_f32s()?;
        r.expect_end()?;
        if seg_version != m.version {
            return Err(StorageError::Corrupt(format!(
                "{} claims v{seg_version}, manifest says v{}",
                seg_path.display(),
                m.version
            )));
        }
        if params.len() != m.param_count as usize || digest_f32s(&params) != m.params_digest {
            return Err(StorageError::Corrupt(format!(
                "{}: parameters do not match their manifest row",
                seg_path.display()
            )));
        }
        rows.push(SnapshotRow {
            version: m.version,
            model: m.model,
            iteration: m.iteration,
            params: Arc::new(params),
            notes: m.notes,
            published_ms: m.published_ms,
        });
    }
    let state = RegistryState {
        next,
        active,
        staged,
        rows,
    };
    SnapshotRegistry::from_state(project, spec.clone(), state)
        .map(Some)
        .map_err(StorageError::Corrupt)
}

/// Retention GC with durability folded in: evict in memory via
/// `gc_keep_latest`, then persist — the dropped versions' segment files
/// are swept by the save.  Returns the dropped handles.
pub fn gc(
    dir: &Path,
    reg: &mut SnapshotRegistry,
    keep: usize,
) -> Result<Vec<crate::serve::ModelVersion>> {
    let dropped = reg.gc_keep_latest(keep);
    save(dir, reg)?;
    Ok(dropped)
}

/// Segment versions currently on disk (ascending) — test/inspection aid.
/// Determinism audit: `read_dir` order is OS-dependent; the result is
/// sorted before it can reach any observable state.
pub fn segment_versions(dir: &Path) -> Result<Vec<u64>> {
    let mut out = Vec::new();
    for entry in fs::read_dir(dir)? {
        if let Some(v) = entry?.file_name().to_str().and_then(parse_segment_name) {
            out.push(v);
        }
    }
    out.sort_unstable();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TensorSpec;
    use std::path::PathBuf;

    const P: ProjectId = ProjectId::new(0);

    fn spec() -> ModelSpec {
        ModelSpec {
            name: "toy".into(),
            param_count: 4,
            batch_size: 2,
            micro_batches: vec![2, 1],
            input: vec![2, 1, 1],
            classes: 2,
            tensors: vec![TensorSpec {
                name: "w".into(),
                shape: vec![4],
                offset: 0,
                size: 4,
                fan_in: 2,
            }],
            artifacts: Default::default(),
        }
    }

    fn test_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("mlitb-regstore-{}-{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn populated_registry() -> SnapshotRegistry {
        let mut reg = SnapshotRegistry::new(P, spec());
        for i in 0..3u64 {
            reg.publish_params(vec![i as f32; 4], i * 10, format!("v{}", i + 1), i as f64)
                .unwrap();
        }
        reg.stage_params(vec![9.0; 4], 40, "in flight".into(), 9.0)
            .unwrap();
        reg.activate(reg.handle(2)).unwrap(); // rollback to v2
        reg
    }

    #[test]
    fn save_load_roundtrip_restores_warm() {
        let dir = test_dir("roundtrip");
        let reg = populated_registry();
        save(&dir, &reg).unwrap();
        assert_eq!(segment_versions(&dir).unwrap(), vec![1, 2, 3, 4]);
        let warm = load(&dir, P, &spec()).unwrap().unwrap();
        assert_eq!(warm.export_state(), reg.export_state());
        assert_eq!(warm.active().unwrap().version, reg.handle(2));
        assert!(warm.is_staged(warm.handle(4)));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_dir_loads_none_and_empty_registry_saves() {
        let dir = test_dir("empty");
        assert!(load(&dir, P, &spec()).is_err(), "missing dir is an io error");
        fs::create_dir_all(&dir).unwrap();
        assert!(load(&dir, P, &spec()).unwrap().is_none());
        let reg = SnapshotRegistry::new(P, spec());
        save(&dir, &reg).unwrap();
        let warm = load(&dir, P, &spec()).unwrap().unwrap();
        assert!(warm.is_empty());
        assert!(warm.active().is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_deletes_segment_files_with_no_orphans() {
        let dir = test_dir("gc");
        let mut reg = populated_registry();
        save(&dir, &reg).unwrap();
        // keep=1 → v1 and v3 evictable; v2 (active) and v4 (staged,
        // newest) survive.  The dropped versions' segments vanish.
        let dropped = gc(&dir, &mut reg, 1).unwrap();
        assert_eq!(dropped, vec![reg.handle(1), reg.handle(3)]);
        assert_eq!(segment_versions(&dir).unwrap(), vec![2, 4]);
        let warm = load(&dir, P, &spec()).unwrap().unwrap();
        assert_eq!(warm.export_state(), reg.export_state());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_pointing_at_deleted_segment_errors() {
        let dir = test_dir("missing-seg");
        let reg = populated_registry();
        save(&dir, &reg).unwrap();
        fs::remove_file(dir.join(segment_file_name(2))).unwrap();
        let err = load(&dir, P, &spec()).unwrap_err();
        assert!(err.to_string().contains("missing"), "{err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_segment_fails_its_digest_check() {
        let dir = test_dir("bitflip");
        let reg = populated_registry();
        save(&dir, &reg).unwrap();
        let seg = dir.join(segment_file_name(3));
        let mut bytes = fs::read(&seg).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        fs::write(&seg, bytes).unwrap();
        assert!(load(&dir, P, &spec()).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn resave_after_new_publications_is_incremental() {
        let dir = test_dir("incremental");
        let mut reg = populated_registry();
        save(&dir, &reg).unwrap();
        reg.publish_params(vec![7.0; 4], 50, String::new(), 12.0).unwrap();
        save(&dir, &reg).unwrap();
        assert_eq!(segment_versions(&dir).unwrap(), vec![1, 2, 3, 4, 5]);
        let warm = load(&dir, P, &spec()).unwrap().unwrap();
        assert_eq!(warm.export_state(), reg.export_state());
        let _ = fs::remove_dir_all(&dir);
    }
}
