//! # MLitB — Machine Learning in the Browser, reproduced as a Rust+JAX stack
//!
//! This crate reproduces the distributed-training system of *"MLitB:
//! Machine Learning in the Browser"* (Meeds, Hendriks, Al Faraby, Bruntink,
//! Welling; 2014): a master/slave **synchronized map-reduce** framework for
//! training neural networks with distributed SGD over a dynamic fleet of
//! heterogeneous, unreliable clients.
//!
//! The browser fleet of the paper is replaced by a simulated client fleet
//! (discrete-event, virtual clock); the JavaScript NN (ConvNetJS) is
//! replaced by JAX/Pallas models AOT-compiled to HLO and executed through
//! the PJRT C API (`runtime`).  Coordination logic — the five-step master
//! event loop, pie-cutter data allocation, latency-adaptive work budgets,
//! AdaGrad reduce, JSON research closures — is implemented faithfully.
//!
//! The paper's second pillar — ML *prediction* "to the public at large" —
//! is the [`serve`] subsystem, grown into §3.1's multi-tenant shape: a
//! `ControlPlane` hosting several projects (typed `ProjectId` /
//! `ModelVersion` handles, one snapshot registry per project, weighted
//! fair-share admission), admission + micro-batching over the same
//! compiled artifacts, an LRU prediction cache, and simulated open-loop
//! request fleets.  [`cosim`] couples the two pillars on one shared
//! virtual clock: live masters publish byte-accounted snapshots
//! mid-traffic (transfers cross a shared egress budget before
//! activation; hot swap with answer-consistency guarantees and
//! traffic-driven registry GC) while a staleness probe measures how far
//! served answers lag each project's master.
//!
//! Layer map (see `DESIGN.md`):
//! * L1/L2 — `python/compile/` (build time only; never on the run path).
//! * L3 — this crate: [`coordinator`] (master server), [`client`]
//!   (simulated fleet), [`data`] (data server), [`allocation`]
//!   (pie-cutter), [`params`] (optimizers + the parameter-sharded
//!   multi-threaded reduce), [`runtime`] (PJRT engine),
//!   [`serve`] (prediction serving), [`cosim`] (serve × train
//!   co-simulation), [`storage`] (durable state plane: iteration WAL,
//!   checkpoint/replay recovery, persistent snapshot registry), plus the
//!   from-scratch substrates
//!   [`faults`] (seeded fault-injection plane: disconnect storms,
//!   stragglers, upload loss, hostile gradients),
//!   [`json`], [`rng`], [`netsim`], [`metrics`], [`trace`] (virtual-clock
//!   span tracer with Perfetto export), [`cli`], [`bench`], [`testing`],
//!   and [`analysis`] (the `mlitb lint` determinism analyzer that keeps
//!   all of the above honest — see DESIGN.md "Determinism discipline").

pub mod allocation;
pub mod analysis;
pub mod bench;
pub mod cli;
pub mod client;
pub mod coordinator;
pub mod cosim;
pub mod data;
pub mod faults;
pub mod json;
pub mod metrics;
pub mod model;
pub mod netsim;
pub mod params;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod storage;
pub mod testing;
pub mod trace;

/// Crate version string used in research closures and CLI output.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
