//! Fig 5 reproduction: effects of scaling on optimization.
//!
//! "Convergence of the NN is measured in terms of test error after 50 and
//! 100 iterations.  Each point represents approximately the same
//! wall-clock time." (§3.5).  The capacity policy (3000 vectors/node —
//! scaled 1:5 here) means more nodes cover more of the training set:
//! 1 node trains on 3/60 of the data; at 20 nodes the full set is covered
//! and the error flattens.
//!
//! Gradients are REAL (PJRT engine over the AOT convnet) — this bench is
//! the correctness half of the scaling study and takes a few minutes.
//!
//!     cargo bench --bench fig5_convergence             # {1,4,8,20} nodes
//!     cargo bench --bench fig5_convergence -- --full   # adds {2,16,32}
//!     cargo bench --bench fig5_convergence -- --fast   # {1,20}, 40 iters

use mlitb::metrics::Table;
use mlitb::runtime::Engine;
use mlitb::sim::{SimConfig, Simulation};

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let full = std::env::args().any(|a| a == "--full");
    let nodes: Vec<usize> = if fast {
        vec![1, 20]
    } else if full {
        vec![1, 2, 4, 8, 16, 20, 32]
    } else {
        vec![1, 4, 8, 20]
    };
    let iters: u64 = if fast { 40 } else { 100 };
    let (mid, end) = (iters / 2, iters);

    // 1:5 scale of the paper's experiment (identical coverage structure):
    // 12k corpus, 600-vector capacity → full coverage at 20 nodes, and a
    // single node sees 600/12000 = 1/20 ≙ the paper's 3000/60000.
    let train_size = 12_000;
    let capacity = 600;

    let mut engine = Engine::from_default_artifacts().expect("run `make artifacts`");
    engine.load_model("mnist_conv").expect("compile model");
    let spec = engine.spec("mnist_conv").unwrap().clone();

    println!(
        "Fig 5: test error after {mid}/{end} iterations vs fleet size\n\
         (real gradients; corpus {train_size}, capacity {capacity}/node — 1:5 of the paper)\n"
    );
    let mut table = Table::new(
        "Fig 5 — convergence vs fleet size (same virtual wall-clock)",
        &[
            "nodes",
            "coverage",
            &format!("err @{mid}"),
            &format!("err @{end}"),
            "final loss",
        ],
    );
    for &n in &nodes {
        let mut cfg = SimConfig::paper_scaling(n, &spec);
        cfg.iterations = iters;
        cfg.train_size = train_size;
        cfg.test_size = 1_000;
        cfg.master.capacity = capacity;
        cfg.master.learning_rate = 0.05;
        cfg.track_every = mid.max(1);
        cfg.power_scale = 0.12; // virtual device speed (shape-invariant)
        cfg.seed = 5;
        let mut sim = Simulation::new(cfg, spec.clone(), &mut engine);
        let coverage = sim.coverage();
        let report = sim.run().expect("sim run");
        let err_mid = report.test_error_at(mid - 1);
        let err_end = report.test_error_at(end - 1);
        let last_loss = report
            .timeline
            .records()
            .iter()
            .rev()
            .find_map(|r| r.loss);
        table.row(vec![
            n.to_string(),
            format!("{:.0}%", coverage * 100.0),
            err_mid.map_or("-".into(), |e| format!("{e:.4}")),
            err_end.map_or("-".into(), |e| format!("{e:.4}")),
            last_loss.map_or("-".into(), |l| format!("{l:.4}")),
        ]);
        println!("  [{n} nodes done: {}]", report.summary());
    }
    table.print();
    println!(
        "expected shape (paper): error falls with node count (more data covered)\n\
         and flattens once coverage reaches 100% (20 nodes); @{end} ≤ @{mid} everywhere."
    );
}
