//! Fig 8 reproduction: tracking mode — classification error over updates.
//!
//! "A test dataset can be loaded and its classification error rate tracked
//! over iterations; here using a NN trained on CIFAR-10." (§3.6, Fig 8).
//! A tracker worker re-evaluates the test set after each broadcast; the
//! bench prints the error series for the synthetic-CIFAR convnet — the
//! same monotone-decreasing-with-noise curve the paper shows over its
//! first ~600 updates (scaled here to keep the run in CI time).
//!
//!     cargo bench --bench fig8_tracking             # 120 iterations
//!     cargo bench --bench fig8_tracking -- --fast   # 30 iterations

use mlitb::metrics::Table;
use mlitb::runtime::Engine;
use mlitb::sim::{SimConfig, Simulation};

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let iters: u64 = if fast { 30 } else { 120 };
    let track_every: u64 = if fast { 5 } else { 10 };

    let mut engine = Engine::from_default_artifacts().expect("run `make artifacts`");
    engine.load_model("cifar_conv").expect("compile model");
    let spec = engine.spec("cifar_conv").unwrap().clone();

    println!(
        "Fig 8: tracking-mode classification error, {} ({} params), {iters} updates\n",
        spec.name, spec.param_count
    );
    let mut cfg = SimConfig::paper_scaling(4, &spec);
    cfg.iterations = iters;
    cfg.train_size = 8_000;
    cfg.test_size = 640;
    cfg.master.capacity = 2_000;
    cfg.master.learning_rate = 0.05;
    cfg.track_every = track_every;
    cfg.power_scale = 0.12;
    cfg.seed = 8;

    let mut sim = Simulation::new(cfg, spec.clone(), &mut engine);
    let report = sim.run().expect("sim run");

    let mut table = Table::new(
        "Fig 8 — test error vs parameter updates (tracker worker)",
        &["iteration", "test error", "train loss"],
    );
    let mut series = Vec::new();
    for r in report.timeline.records() {
        if let Some(err) = r.test_error {
            series.push(err);
            table.row(vec![
                r.iteration.to_string(),
                format!("{err:.4}"),
                r.loss.map_or("-".into(), |l| format!("{l:.4}")),
            ]);
        }
    }
    table.print();
    let first = series.first().copied().unwrap_or(f64::NAN);
    let last = series.last().copied().unwrap_or(f64::NAN);
    println!(
        "expected shape (paper): error decreases over updates; got {first:.3} -> {last:.3} ({})",
        if last < first { "decreasing ✓" } else { "NOT decreasing ✗" }
    );
}
