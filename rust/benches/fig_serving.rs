//! Serving throughput/latency vs offered load — the prediction-path
//! analogue of the Fig 4 training sweep.
//!
//! Sweeps open-loop arrival rates across link profiles and reports
//! completion, shedding, cache hit rate, mean executed batch size and
//! end-to-end latency percentiles.  Gradients never run here; prediction
//! uses the deterministic modeled scorer, so the bench works without AOT
//! artifacts and isolates *coordination* cost (queueing, batching,
//! caching) exactly as the training sweep isolates master ingestion.
//!
//!     cargo bench --bench fig_serving            # full sweep
//!     cargo bench --bench fig_serving -- --fast  # fewer points
//!
//! Expected shape: at low load, latency ≈ link RTT + one batch wait; as
//! offered load approaches the executor's service rate, batches fill up
//! (amortizing per-batch overhead and *raising* sustainable throughput);
//! past saturation, the admission queue sheds and p99 plateaus at
//! queue-depth × service time instead of growing without bound.

use mlitb::metrics::Table;
use mlitb::model::init_params;
use mlitb::netsim::LinkProfile;
use mlitb::runtime::ModeledCompute;
use mlitb::serve::{
    demo_spec, BatchPolicy, ClientSpec, ControlPlane, FleetConfig, ProjectId, RouterConfig,
    ServeConfig, ServeSim, ServerProfile,
};

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    // Aggregate offered load (requests/second across the whole fleet).
    let rates: &[f64] = if fast {
        &[50.0, 400.0, 1600.0]
    } else {
        &[25.0, 50.0, 100.0, 200.0, 400.0, 800.0, 1600.0]
    };
    let links = [LinkProfile::Lan, LinkProfile::Wifi, LinkProfile::Cellular];
    let duration_s = if fast { 10.0 } else { 20.0 };
    let clients = 16usize;

    let spec = demo_spec();
    let params = init_params(&spec, 1);
    println!(
        "serving sweep — {} ({} params, batch variants {:?}), {clients} clients, {duration_s}s horizon\n",
        spec.name, spec.param_count, spec.micro_batches
    );

    let mut table = Table::new(
        "serving — throughput & latency vs offered load",
        &[
            "link",
            "offered rps",
            "completed",
            "shed",
            "hit rate",
            "mean batch",
            "p50 ms",
            "p95 ms",
            "p99 ms",
            "served rps",
        ],
    );
    for &link in &links {
        for &rate in rates {
            let cfg = ServeConfig {
                fleets: vec![FleetConfig {
                    groups: vec![ClientSpec {
                        link,
                        rate_rps: rate / clients as f64,
                        count: clients,
                    }],
                    duration_s,
                    input_pool: 400,
                    seed: 7,
                }],
                policy: BatchPolicy::default(),
                server: ServerProfile::default(),
                // Single PR-1-style endpoint: this sweep isolates
                // batching/caching; routing gets its own fig_routing.
                router: RouterConfig::single(),
                shard_profiles: Vec::new(),
                drained_shards: Vec::new(),
                cache_capacity: 2048,
                response_bytes: 256,
                keep_log: false,
            };
            let mut plane = ControlPlane::single(spec.clone());
            plane
                .registry_mut(ProjectId::new(0))
                .publish_params(params.clone(), 0, "bench".into(), 0.0)
                .expect("publish snapshot");
            let mut compute = ModeledCompute {
                param_count: spec.param_count,
            };
            let mut sim = ServeSim::new(cfg, plane, &mut compute);
            let report = sim.run().expect("serve sim");
            let lat = report.latency();
            table.row(vec![
                link.name().to_string(),
                format!("{rate:.0}"),
                report.completed.to_string(),
                report.rejected.to_string(),
                format!("{:.2}", report.hit_rate()),
                format!("{:.1}", report.mean_batch()),
                format!("{:.1}", lat.median()),
                format!("{:.1}", lat.p95()),
                format!("{:.1}", lat.quantile(0.99)),
                format!("{:.0}", report.throughput_rps()),
            ]);
        }
    }
    table.print();
    println!(
        "batching earns its keep where offered load exceeds the single-request\n\
         service rate: mean batch grows toward the compiled maximum and served\n\
         rps keeps climbing after the unbatched knee."
    );
}
