//! Fig 4 reproduction: effects of scaling on power and latency.
//!
//! "Power — measured as the number of data vectors processed per second —
//! scales linearly until 64 nodes, when the increase in latency jumps."
//! (§3.5).  The sweep doubles the fleet 1→96 and reports power (vectors/s)
//! and mean slave↔master latency per node count, plus the ideal-linear
//! column the paper draws in grey.
//!
//! Coordination throughput is what's under test, so gradients are modeled
//! (see DESIGN.md); the latency knee comes from the calibrated master
//! ingestion model (serial drain of ~94 KB gradient messages).
//!
//!     cargo bench --bench fig4_scaling            # paper sweep to 96
//!     cargo bench --bench fig4_scaling -- --fast  # fewer points
//!     cargo bench --bench fig4_scaling -- --reduce-mode sharded:4
//!                                                 # §5 param-sharded reduce

use mlitb::cli::Args;
use mlitb::metrics::Table;
use mlitb::model::Manifest;
use mlitb::netsim::ReduceMode;
use mlitb::runtime::ModeledCompute;
use mlitb::sim::{SimConfig, Simulation};

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast");
    let reduce_mode =
        ReduceMode::parse(args.get_or("reduce-mode", "message")).expect("--reduce-mode");
    let merge_ns = args.get_f64("merge-ns", f64::NAN).expect("--merge-ns");
    let nodes: Vec<usize> = if fast {
        vec![1, 4, 16, 64, 96]
    } else {
        vec![1, 2, 4, 8, 16, 32, 48, 64, 80, 96]
    };
    let iters = if fast { 10 } else { 25 };

    // Coordination is what's under test, so a missing artifacts manifest
    // (CI containers) falls back to the built-in demo spec — only the
    // gradient-message size changes, not the sweep's shape.
    let spec = match Manifest::load_default() {
        Ok(m) => m.model("mnist_conv").expect("mnist_conv").clone(),
        Err(_) => {
            println!("note: no artifacts manifest — using the built-in demo spec");
            mlitb::serve::demo_spec()
        }
    };
    println!(
        "Fig 4: paper scaling experiment — {} ({} params, {:.1} KB gradient msg), T=4s, {iters} iters/point, reduce={}\n",
        spec.name,
        spec.param_count,
        spec.grad_message_bytes() as f64 / 1024.0,
        reduce_mode.name()
    );

    let mut table = Table::new(
        "Fig 4 — power & latency vs fleet size",
        &[
            "nodes",
            "power (vec/s)",
            "ideal linear",
            "efficiency",
            "mean latency (ms)",
            "max latency (ms)",
        ],
    );
    let mut per_node_power = None;
    for &n in &nodes {
        let mut cfg = SimConfig::paper_scaling(n, &spec);
        cfg.iterations = iters;
        cfg.seed = 4;
        cfg.master.master_model.reduce_mode = reduce_mode;
        if merge_ns.is_finite() {
            cfg.master.master_model.merge_ns_per_param = merge_ns;
        }
        let mut compute = ModeledCompute {
            param_count: spec.param_count,
        };
        let mut sim = Simulation::new(cfg, spec.clone(), &mut compute);
        let report = sim.run().expect("sim run");
        let per_node = per_node_power.get_or_insert(report.power_vps / n as f64);
        let ideal = *per_node * n as f64;
        let max_lat = report
            .timeline
            .records()
            .iter()
            .map(|r| r.max_latency_ms)
            .fold(0.0f64, f64::max);
        table.row(vec![
            n.to_string(),
            format!("{:.0}", report.power_vps),
            format!("{:.0}", ideal),
            format!("{:.2}", report.power_vps / ideal),
            format!("{:.1}", report.mean_latency_ms),
            format!("{:.1}", max_lat),
        ]);
    }
    table.print();
    println!(
        "expected shape (paper): efficiency ≈1.0 through 64 nodes, then latency jumps\n\
         and power gains flatten as the single master saturates."
    );
}
