//! Multi-tenant sweep: projects × offered rate × publication budget —
//! the control-plane PR's acceptance figure.
//!
//! Three claims, one table each:
//!
//! 1. **Fair share holds.**  A hot project overloading the shared tier
//!    is shed at its own weighted cap; the cold project riding the same
//!    shards keeps a bounded (near-zero) shed rate.  With fair share
//!    disabled the hot backlog fills every queue and the cold project
//!    sheds at nearly the hot rate.
//! 2. **Publication is byte-accounted.**  Snapshots charge master-egress
//!    bytes and activate only when their transfer completes: shrinking
//!    the shared bytes/min budget grows the activation lag (iterations
//!    between the publish decision and the hot swap) and with it the
//!    served staleness — concurrent publishers queue on one link.
//! 3. **Isolation.**  Per-project staleness percentiles come from
//!    per-project traces; one project's publications never stamp the
//!    other's answers.
//!
//!     cargo bench --bench fig_multitenant            # full sweep
//!     cargo bench --bench fig_multitenant -- --fast  # fewer points
//!
//! Everything runs on the modeled backends (no artifacts needed).

use mlitb::cosim::{run_cosim, CosimConfig, CosimProject, PublicationPolicy};
use mlitb::metrics::Table;
use mlitb::netsim::LinkProfile;
use mlitb::runtime::{Compute, DriftingCompute, ModeledCompute};
use mlitb::serve::{
    demo_spec, BatchPolicy, ClientSpec, ControlPlane, FleetConfig, ProjectId, RouterConfig,
    RoutingPolicy, ServeConfig, ServeReport, ServeSim, ServerProfile,
};
use mlitb::sim::SimConfig;

fn fleet(rate_rps: f64, clients: usize, duration_s: f64, seed: u64) -> FleetConfig {
    FleetConfig {
        groups: vec![ClientSpec {
            link: LinkProfile::Lan,
            rate_rps,
            count: clients,
        }],
        duration_s,
        input_pool: 512,
        seed,
    }
}

/// Two projects behind one shared tier: project 0 hot, project 1 cold.
fn serve_cfg(hot_rps: f64, cold_rps: f64, duration_s: f64, fair_share: bool) -> ServeConfig {
    ServeConfig {
        fleets: vec![
            fleet(hot_rps, 12, duration_s, 7),
            fleet(cold_rps, 4, duration_s, 8),
        ],
        policy: BatchPolicy {
            queue_depth: 64,
            ..BatchPolicy::default()
        },
        server: ServerProfile::default(),
        router: RouterConfig {
            shards: 2,
            policy: RoutingPolicy::JoinShortestQueue,
            fair_share,
            ..RouterConfig::single()
        },
        shard_profiles: Vec::new(),
        drained_shards: Vec::new(),
        cache_capacity: 0,
        response_bytes: 256,
        keep_log: false,
    }
}

fn serve_run(cfg: ServeConfig) -> ServeReport {
    let spec = demo_spec();
    let mut plane = ControlPlane::new();
    for seed in [41u64, 42] {
        let p = plane.register(spec.clone(), 1.0);
        plane
            .registry_mut(p)
            .publish_params(mlitb::model::init_params(&spec, seed), 0, "bench".into(), 0.0)
            .expect("publish");
    }
    let mut compute = ModeledCompute {
        param_count: spec.param_count,
    };
    ServeSim::new(cfg, plane, &mut compute)
        .run()
        .expect("serve sim")
}

fn cosim_cfg(iters: u64, egress_bytes_per_min: f64) -> CosimConfig {
    let spec = demo_spec();
    let duration_s = iters as f64 * 1.0;
    let project = |seed: u64| {
        let mut train = SimConfig::paper_scaling(2, &spec);
        train.iterations = iters;
        train.train_size = 800;
        train.test_size = 128;
        train.track_every = 4;
        train.master.iter_duration_s = 1.0;
        train.seed = seed;
        CosimProject {
            spec: spec.clone(),
            train,
            publish: PublicationPolicy::every(2),
            retain: 3,
            weight: 1.0,
        }
    };
    CosimConfig {
        projects: vec![project(5), project(6)],
        serve: ServeConfig {
            fleets: vec![
                fleet(12.0, 8, duration_s, 23),
                fleet(12.0, 8, duration_s, 24),
            ],
            policy: BatchPolicy::default(),
            server: ServerProfile::default(),
            router: RouterConfig {
                shards: 2,
                policy: RoutingPolicy::JoinShortestQueue,
                coalesce: true,
                ..RouterConfig::single()
            },
            shard_profiles: Vec::new(),
            drained_shards: Vec::new(),
            cache_capacity: 1_024,
            response_bytes: 256,
            keep_log: false,
        },
        egress_bytes_per_min,
        measure_delta: true,
    }
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let spec = demo_spec();
    let snapshot_kb = spec.param_count as f64 * 4.0 / 1000.0;
    println!(
        "multitenant sweep — {} ({} params, {snapshot_kb:.0} KB/snapshot), 2 projects \
         behind one shared tier\n",
        spec.name, spec.param_count
    );

    // ── 1. fair-share admission under a hot/cold split ────────────────
    // Hot project ≈ 2× one shard's service rate; cold project trickles.
    let duration_s = if fast { 5.0 } else { 10.0 };
    let hot_rps = 500.0; // × 12 clients = 6000 rps over ~3000 rps of tier
    let cold_rps = 10.0; // × 4 clients = 40 rps
    let mut fair_table = Table::new(
        "fair share — hot project overload, cold project trickle (2 shards jsq, depth 64)",
        &[
            "fair share", "project", "offered", "completed", "shed", "shed rate",
        ],
    );
    let mut verdict_fair: Vec<(bool, f64, f64)> = Vec::new(); // (fair, hot shed, cold shed)
    for fair_share in [true, false] {
        let report = serve_run(serve_cfg(hot_rps, cold_rps, duration_s, fair_share));
        let hot = report.project(ProjectId::new(0));
        let cold = report.project(ProjectId::new(1));
        for stats in [hot, cold] {
            fair_table.row(vec![
                if fair_share { "on".into() } else { "off".into() },
                stats.project.to_string(),
                stats.offered.to_string(),
                stats.completed.to_string(),
                stats.rejected.to_string(),
                format!("{:.3}", stats.shed_rate()),
            ]);
        }
        verdict_fair.push((fair_share, hot.shed_rate(), cold.shed_rate()));
    }
    fair_table.print();
    for (fair, hot_shed, cold_shed) in &verdict_fair {
        if *fair {
            let mark = if *cold_shed < 0.05 && *hot_shed > 0.2 { "✓" } else { "✗" };
            println!(
                "  {mark} fair share on: hot sheds {hot_shed:.3} at its cap, cold stays \
                 bounded at {cold_shed:.3}"
            );
        } else {
            let mark = if *cold_shed > 0.1 { "✓" } else { "✗" };
            println!(
                "  {mark} fair share off: the hot backlog starves the cold project \
                 (cold shed {cold_shed:.3})"
            );
        }
    }
    println!();

    // ── 2. publication budget: egress bytes delay activation ──────────
    let iters: u64 = if fast { 8 } else { 16 };
    // ~51 KB/snapshot at T=1s: 12 MB/min ≈ instant, 1 MB/min ≈ 3
    // iterations on the link, 0.5 MB/min ≈ 6 — and the two projects'
    // transfers queue behind each other.
    let budgets: &[(f64, &str)] = if fast {
        &[(0.0, "∞"), (1.0e6, "1.0")]
    } else {
        &[(0.0, "∞"), (12.0e6, "12.0"), (1.0e6, "1.0"), (0.5e6, "0.5")]
    };
    let mut pub_table = Table::new(
        "publication budget — activation lag & staleness vs egress MB/min (2 projects, publish every 2)",
        &[
            "egress MB/min", "pubs", "egress KB", "mean lag (iters)", "max lag",
            "p0 age p50", "p1 age p50", "completed",
        ],
    );
    let mut lags: Vec<(String, f64)> = Vec::new();
    for &(budget, label) in budgets {
        let cfg = cosim_cfg(iters, budget);
        let mut train_a = DriftingCompute { param_count: spec.param_count };
        let mut train_b = DriftingCompute { param_count: spec.param_count };
        let mut serve_c = ModeledCompute { param_count: spec.param_count };
        let report = run_cosim(
            &cfg,
            vec![
                &mut train_a as &mut dyn Compute,
                &mut train_b as &mut dyn Compute,
            ],
            &mut serve_c,
        )
        .expect("cosim run");
        let live: Vec<_> = report
            .publications
            .iter()
            .filter(|p| p.bytes > 0)
            .collect();
        let mean_lag = if live.is_empty() {
            0.0
        } else {
            live.iter().map(|p| p.activation_lag_iters() as f64).sum::<f64>() / live.len() as f64
        };
        let max_lag = live
            .iter()
            .map(|p| p.activation_lag_iters())
            .max()
            .unwrap_or(0);
        let age = |i: u32| {
            report
                .staleness
                .for_project(ProjectId::new(i))
                .age_iters_summary()
                .median()
        };
        pub_table.row(vec![
            label.to_string(),
            report.publications.len().to_string(),
            format!("{:.0}", report.egress_bytes as f64 / 1000.0),
            format!("{mean_lag:.1}"),
            max_lag.to_string(),
            format!("{:.1}", age(0)),
            format!("{:.1}", age(1)),
            report.serve.completed.to_string(),
        ]);
        lags.push((label.to_string(), mean_lag));
    }
    pub_table.print();
    let monotone = lags.windows(2).all(|w| w[0].1 <= w[1].1 + 1e-9);
    let mark = if monotone { "✓" } else { "✗" };
    let pairs: Vec<String> = lags
        .iter()
        .map(|(label, lag)| format!("{label} MB/min: {lag:.1} it"))
        .collect();
    println!(
        "  {mark} activation lag grows as the egress budget shrinks ({})",
        pairs.join(", ")
    );
    println!(
        "\n  a publication is no longer free: its bytes queue on the shared egress link,\n\
         activation waits for the transfer, and a starved budget turns straight into\n\
         staleness — the dial `--egress-mb-min` trades."
    );
}
