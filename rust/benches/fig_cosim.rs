//! Cosim sweep: the staleness-vs-latency frontier — publication cadence ×
//! shard count under live training traffic.
//!
//! Two claims, one table + verdicts:
//!
//! 1. **Staleness tracks cadence.**  Served-prediction staleness (the
//!    age, in training iterations, of the snapshot behind each answer)
//!    decreases monotonically as the master publishes more often; the
//!    prediction delta against the live parameters shrinks with it.
//! 2. **Freshness is (nearly) free at these loads.**  Hot-swapping
//!    versions mid-traffic keeps p99 latency within the serving-only
//!    baseline envelope (the publish-never run) — swaps cost cache
//!    warmth, not answer latency.
//!
//!     cargo bench --bench fig_cosim            # full sweep
//!     cargo bench --bench fig_cosim -- --fast  # fewer points
//!
//! Training runs on `DriftingCompute` (deterministic parameter motion —
//! zero-gradient modeled compute would make every snapshot identical and
//! the staleness delta trivially zero).

use mlitb::cosim::{run_cosim, CosimConfig, CosimProject, PublicationPolicy};
use mlitb::metrics::Table;
use mlitb::netsim::LinkProfile;
use mlitb::runtime::{Compute, DriftingCompute, ModeledCompute};
use mlitb::serve::{
    demo_spec, BatchPolicy, ClientSpec, FleetConfig, RouterConfig, RoutingPolicy, ServeConfig,
    ServerProfile,
};
use mlitb::sim::SimConfig;

const CLIENTS: usize = 12;
const RATE_RPS: f64 = 20.0; // per client → 240 rps offered

fn config(iters: u64, shards: usize, publish_every: u64) -> CosimConfig {
    let spec = demo_spec();
    let mut train = SimConfig::paper_scaling(3, &spec);
    train.iterations = iters;
    train.train_size = 1_500;
    train.test_size = 256;
    train.track_every = 4;
    train.master.iter_duration_s = 1.0;
    train.seed = 5;
    let serve = ServeConfig {
        fleets: vec![FleetConfig {
            groups: vec![ClientSpec {
                link: LinkProfile::Wifi,
                rate_rps: RATE_RPS,
                count: CLIENTS,
            }],
            duration_s: iters as f64 * train.master.iter_duration_s,
            input_pool: 256,
            seed: 23,
        }],
        policy: BatchPolicy::default(),
        server: ServerProfile::default(),
        router: RouterConfig {
            shards,
            policy: RoutingPolicy::JoinShortestQueue,
            coalesce: true,
            ..RouterConfig::single()
        },
        shard_profiles: Vec::new(),
        drained_shards: Vec::new(),
        cache_capacity: 2_048,
        response_bytes: 256,
        keep_log: false,
    };
    CosimConfig {
        projects: vec![CosimProject {
            spec,
            train,
            publish: PublicationPolicy::every(publish_every),
            retain: 3,
            weight: 1.0,
        }],
        serve,
        egress_bytes_per_min: 0.0,
        measure_delta: true,
    }
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let iters: u64 = if fast { 12 } else { 24 };
    let shard_counts: &[usize] = if fast { &[2] } else { &[1, 2] };
    let cadences: &[u64] = if fast { &[1, 6] } else { &[1, 4, 12] };
    let spec = demo_spec();
    println!(
        "cosim sweep — {} ({} params), {CLIENTS} clients × {RATE_RPS:.0} rps, {iters} iterations \
         of live training (drifting modeled gradients)\n",
        spec.name, spec.param_count
    );

    let mut table = Table::new(
        "staleness vs latency — publication cadence × shards",
        &[
            "shards", "publish every", "pubs", "gc evicted", "age p50 it", "age p99 it",
            "age p50 ms", "delta mean", "class flips", "lat p50 ms", "lat p99 ms", "completed",
        ],
    );
    struct Verdict {
        shards: usize,
        /// (cadence, mean snapshot age in iterations) per run.
        ages: Vec<(u64, f64)>,
        p99s: Vec<f64>,
        base_p99: f64,
    }
    let mut verdicts: Vec<Verdict> = Vec::new();
    for &shards in shard_counts {
        // Baseline: publish once, never swap — the serving-only envelope.
        let baseline = {
            let cfg = config(iters, shards, 0);
            let mut train_c = DriftingCompute { param_count: spec.param_count };
            let mut serve_c = ModeledCompute { param_count: spec.param_count };
            run_cosim(&cfg, vec![&mut train_c as &mut dyn Compute], &mut serve_c)
                .expect("cosim baseline")
        };
        let base_p99 = baseline.serve.latency().quantile(0.99);
        let mut ages: Vec<(u64, f64)> = Vec::new();
        let mut p99s: Vec<f64> = Vec::new();
        for &cadence in cadences {
            let cfg = config(iters, shards, cadence);
            let mut train_c = DriftingCompute { param_count: spec.param_count };
            let mut serve_c = ModeledCompute { param_count: spec.param_count };
            let report = run_cosim(&cfg, vec![&mut train_c as &mut dyn Compute], &mut serve_c)
                .expect("cosim run");
            let age_it = report.staleness.age_iters_summary();
            let age_ms = report.staleness.age_ms_summary();
            let lat = report.serve.latency();
            table.row(vec![
                shards.to_string(),
                cadence.to_string(),
                report.publications.len().to_string(),
                report.evicted.to_string(),
                format!("{:.1}", age_it.median()),
                format!("{:.1}", age_it.quantile(0.99)),
                format!("{:.0}", age_ms.median()),
                format!("{:.4}", report.staleness.delta_summary().mean()),
                format!("{:.3}", report.staleness.stale_class_rate()),
                format!("{:.1}", lat.median()),
                format!("{:.1}", lat.quantile(0.99)),
                report.serve.completed.to_string(),
            ]);
            ages.push((cadence, age_it.mean()));
            p99s.push(lat.quantile(0.99));
        }
        // Baseline row (staleness unbounded: the master keeps training).
        let age_it = baseline.staleness.age_iters_summary();
        table.row(vec![
            shards.to_string(),
            "never".into(),
            baseline.publications.len().to_string(),
            baseline.evicted.to_string(),
            format!("{:.1}", age_it.median()),
            format!("{:.1}", age_it.quantile(0.99)),
            format!("{:.0}", baseline.staleness.age_ms_summary().median()),
            format!("{:.4}", baseline.staleness.delta_summary().mean()),
            format!("{:.3}", baseline.staleness.stale_class_rate()),
            format!("{:.1}", baseline.serve.latency().median()),
            format!("{base_p99:.1}"),
            baseline.serve.completed.to_string(),
        ]);
        verdicts.push(Verdict {
            shards,
            ages,
            p99s,
            base_p99,
        });
    }
    table.print();

    for v in &verdicts {
        let monotone = v.ages.windows(2).all(|w| w[0].1 <= w[1].1);
        let mark = if monotone { "✓" } else { "✗" };
        let pairs: Vec<String> = v
            .ages
            .iter()
            .map(|(k, a)| format!("k={k}: {a:.2} it"))
            .collect();
        println!(
            "  {mark} {} shard(s): mean staleness rises monotonically with cadence ({})",
            v.shards,
            pairs.join(", ")
        );
        // Envelope: publishing must not blow up tail latency vs never
        // publishing (swaps cost cache warmth only).
        let envelope = v.base_p99 * 1.5 + 2.0;
        let within = v.p99s.iter().all(|&p| p <= envelope);
        let mark = if within { "✓" } else { "✗" };
        println!(
            "  {mark} {} shard(s): p99 under publication stays within the serving-only \
             envelope ({:.1} ms vs baseline {:.1} ms)",
            v.shards,
            v.p99s.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            v.base_p99
        );
    }
    println!(
        "\n  faster publication ⇒ fresher answers (smaller age + delta) at the cost of cache\n\
         warmth per swap; the frontier above is what `--publish-every` trades."
    );
}
