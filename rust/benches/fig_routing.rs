//! Routing sweep: shards × routing policy × offered load — the serving
//! fleet's analogue of the Fig 4 scaling sweep.
//!
//! Four questions, one table each:
//!
//! 1. **Routing** — with straggler service jitter (`ServerProfile::
//!    jitter`, the realistic regime: GC pauses, contention), work-aware
//!    join-shortest-queue beats round-robin on p99 at high load: RR keeps
//!    feeding a stalled shard while its twin idles, JSQ routes around the
//!    backlog.  Input-affinity pays a balance penalty for cache locality.
//! 2. **Coalescing** — under a duplicate-heavy input pool, deduping
//!    in-flight inputs must shrink executed examples without changing
//!    completion counts.
//! 3. **Autotune** — at low offered load the fixed partial-batch deadline
//!    is pure added latency; the tuned deadline should shed it.  At high
//!    load both fill batches and behave alike.
//! 4. **Shed attribution** — at overload, per-shard stats and per-link
//!    shed rates must reconcile `offered = completed + rejected`.
//!
//!     cargo bench --bench fig_routing            # full sweep
//!     cargo bench --bench fig_routing -- --fast  # fewer points

use mlitb::metrics::Table;
use mlitb::model::init_params;
use mlitb::netsim::LinkProfile;
use mlitb::runtime::ModeledCompute;
use mlitb::serve::{
    demo_spec, BatchPolicy, ClientSpec, ControlPlane, FleetConfig, ProjectId, RouterConfig,
    RoutingPolicy, ServeConfig, ServeReport, ServeSim, ServerProfile,
};

/// Nominal single-shard service capacity (rps) at full batch for the demo
/// spec + default server profile: batch 32 in 2.5 ms overhead + 8 ms
/// compute ≈ 3000 rps.  Offered loads are expressed as a fraction of it.
const CAP_PER_SHARD: f64 = 3_000.0;
const CLIENTS: usize = 24;

fn mixed_fleet(total_rps: f64, duration_s: f64, input_pool: usize, seed: u64) -> FleetConfig {
    let lan = CLIENTS / 3;
    let wifi = CLIENTS / 3;
    let cellular = CLIENTS - lan - wifi;
    let rate_rps = total_rps / CLIENTS as f64;
    FleetConfig {
        groups: vec![
            ClientSpec { link: LinkProfile::Lan, rate_rps, count: lan },
            ClientSpec { link: LinkProfile::Wifi, rate_rps, count: wifi },
            ClientSpec { link: LinkProfile::Cellular, rate_rps, count: cellular },
        ],
        duration_s,
        input_pool,
        seed,
    }
}

fn run(
    fleet: FleetConfig,
    router: RouterConfig,
    queue_depth: usize,
    cache: usize,
    jitter: f64,
) -> ServeReport {
    let spec = demo_spec();
    let cfg = ServeConfig {
        fleets: vec![fleet],
        policy: BatchPolicy {
            queue_depth,
            ..BatchPolicy::default()
        },
        server: ServerProfile {
            jitter,
            ..ServerProfile::default()
        },
        router,
        shard_profiles: Vec::new(),
        drained_shards: Vec::new(),
        cache_capacity: cache,
        response_bytes: 256,
        keep_log: true,
    };
    let mut plane = ControlPlane::single(spec.clone());
    plane
        .registry_mut(ProjectId::new(0))
        .publish_params(init_params(&spec, 1), 0, "bench".into(), 0.0)
        .expect("publish snapshot");
    let mut compute = ModeledCompute {
        param_count: spec.param_count,
    };
    let mut sim = ServeSim::new(cfg, plane, &mut compute);
    sim.run().expect("serve sim")
}

fn router(shards: usize, policy: RoutingPolicy) -> RouterConfig {
    RouterConfig {
        shards,
        policy,
        ..RouterConfig::single()
    }
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let duration_s = if fast { 5.0 } else { 10.0 };
    let spec = demo_spec();
    println!(
        "routing sweep — {} ({} params, batch variants {:?}), {CLIENTS} clients (mixed links), \
         {duration_s}s horizon, ~{CAP_PER_SHARD:.0} rps/shard capacity\n",
        spec.name, spec.param_count, spec.micro_batches
    );

    // ── 1. routing policies under load ────────────────────────────────
    // Straggler jitter 0.5 → mean service factor 1.5 → effective
    // capacity ≈ CAP_PER_SHARD / 1.5 per shard.
    const JITTER: f64 = 0.5;
    let eff_cap = CAP_PER_SHARD / (1.0 + JITTER);
    let rhos: &[f64] = if fast { &[0.85] } else { &[0.6, 0.85] };
    let shard_counts: &[usize] = &[1, 2, 4];
    let policies = [
        RoutingPolicy::RoundRobin,
        RoutingPolicy::JoinShortestQueue,
        RoutingPolicy::InputAffinity,
    ];
    let mut table = Table::new(
        "routing — latency vs shards × policy (jitter 0.5, cache off, coalesce off)",
        &[
            "shards", "policy", "rho", "offered rps", "completed", "shed", "mean batch",
            "p50 ms", "p99 ms", "served rps", "exec min/max per shard",
        ],
    );
    let mut verdict: Vec<(usize, f64, f64, f64)> = Vec::new(); // (shards, rho, rr p99, jsq p99)
    for &shards in shard_counts {
        for &rho in rhos {
            let total_rps = rho * eff_cap * shards as f64;
            let mut p99_rr = 0.0;
            let mut p99_jsq = 0.0;
            for policy in policies {
                // Deep queues: compare queueing delay, not shed truncation.
                let report = run(
                    mixed_fleet(total_rps, duration_s, 4096, 7),
                    router(shards, policy),
                    4096,
                    0,
                    JITTER,
                );
                let lat = report.latency();
                let execs: Vec<u64> =
                    report.per_shard.iter().map(|s| s.batch_examples).collect();
                let min_exec = execs.iter().copied().min().unwrap_or(0);
                let max_exec = execs.iter().copied().max().unwrap_or(0);
                match policy {
                    RoutingPolicy::RoundRobin => p99_rr = lat.quantile(0.99),
                    RoutingPolicy::JoinShortestQueue => p99_jsq = lat.quantile(0.99),
                    RoutingPolicy::InputAffinity => {}
                }
                table.row(vec![
                    shards.to_string(),
                    policy.name().to_string(),
                    format!("{rho:.2}"),
                    format!("{total_rps:.0}"),
                    report.completed.to_string(),
                    report.rejected.to_string(),
                    format!("{:.1}", report.mean_batch()),
                    format!("{:.1}", lat.median()),
                    format!("{:.1}", lat.quantile(0.99)),
                    format!("{:.0}", report.throughput_rps()),
                    format!("{min_exec}/{max_exec}"),
                ]);
            }
            if shards >= 2 {
                verdict.push((shards, rho, p99_rr, p99_jsq));
            }
        }
    }
    table.print();
    for (shards, rho, rr, jsq) in &verdict {
        let mark = if jsq < rr { "✓" } else { "✗" };
        println!(
            "  {mark} {shards} shards @ rho {rho:.2}: jsq p99 {jsq:.1} ms vs rr p99 {rr:.1} ms"
        );
    }
    println!();

    // ── 2. coalescing under a duplicate-heavy pool ────────────────────
    let mut co_table = Table::new(
        "coalescing — duplicate-heavy pool (8 inputs), 2 shards jsq, rho 0.8",
        &[
            "cache", "coalesce", "offered", "completed", "executed", "coalesced", "hits",
            "p50 ms", "p99 ms",
        ],
    );
    for cache in [0usize, 2048] {
        for coalesce in [false, true] {
            let mut rc = router(2, RoutingPolicy::JoinShortestQueue);
            rc.coalesce = coalesce;
            let report = run(
                mixed_fleet(0.8 * CAP_PER_SHARD * 2.0, duration_s, 8, 11),
                rc,
                4096,
                cache,
                0.0, // deterministic service: isolate the coalescing effect
            );
            let lat = report.latency();
            co_table.row(vec![
                if cache == 0 { "off".into() } else { cache.to_string() },
                if coalesce { "on".into() } else { "off".into() },
                report.offered.to_string(),
                report.completed.to_string(),
                report.batch_examples.to_string(),
                report.coalesced.to_string(),
                report.cache_hits.to_string(),
                format!("{:.1}", lat.median()),
                format!("{:.1}", lat.quantile(0.99)),
            ]);
        }
    }
    co_table.print();
    println!(
        "  duplicates that used to execute once per in-flight copy now ride the\n\
         leader's computation: executed examples drop, completions do not.\n"
    );

    // ── 3. batching autotune vs fixed deadline ────────────────────────
    let mut tune_table = Table::new(
        "autotune — tuned max_wait vs fixed 5 ms (1 shard)",
        &["offered rps", "mode", "mean batch", "p50 ms", "p99 ms", "final wait ms"],
    );
    for total_rps in [60.0, 0.85 * CAP_PER_SHARD] {
        for autotune in [false, true] {
            let mut rc = router(1, RoutingPolicy::RoundRobin);
            rc.autotune = autotune;
            let report = run(mixed_fleet(total_rps, duration_s, 4096, 13), rc, 4096, 0, 0.0);
            let lat = report.latency();
            tune_table.row(vec![
                format!("{total_rps:.0}"),
                if autotune { "autotune".into() } else { "fixed".into() },
                format!("{:.1}", report.mean_batch()),
                format!("{:.1}", lat.median()),
                format!("{:.1}", lat.quantile(0.99)),
                format!("{:.2}", report.per_shard[0].max_wait_ms),
            ]);
        }
    }
    tune_table.print();
    println!(
        "  at 60 rps the 5 ms deadline buys no batching — autotune flushes\n\
         immediately and p50 drops by the deadline; near capacity both fill\n\
         batches and converge.\n"
    );

    // ── 4. overload: per-shard stats + per-link shed attribution ──────
    let report = run(
        mixed_fleet(1.4 * CAP_PER_SHARD * 2.0, duration_s, 4096, 17),
        router(2, RoutingPolicy::JoinShortestQueue),
        64,
        0,
        0.0,
    );
    let mut shard_table = Table::new(
        "overload (rho 1.4, 2 shards jsq, depth 64) — per-shard stats",
        &["shard", "routed", "completed", "shed", "batches", "mean batch", "occupancy"],
    );
    for s in &report.per_shard {
        shard_table.row(vec![
            s.shard.to_string(),
            s.routed.to_string(),
            s.completed().to_string(),
            s.rejected.to_string(),
            s.batches.to_string(),
            format!("{:.1}", s.mean_batch()),
            format!(
                "{:.2}",
                s.batch_examples as f64 / (s.batch_examples + s.padded_examples).max(1) as f64
            ),
        ]);
    }
    shard_table.print();

    // Client ids are assigned contiguously per group (lan, wifi, cellular).
    let lan = CLIENTS as u32 / 3;
    let wifi = CLIENTS as u32 / 3;
    let bounds = [
        ("lan", 0u32, lan),
        ("wifi", lan, lan + wifi),
        ("cellular", lan + wifi, CLIENTS as u32),
    ];
    let by_client = report.log.rejections_by_client();
    // Exact per-client offered counts (completed + rejected) — each
    // client's offered load is its own Poisson draw, so dividing by a
    // uniform mean would skew the rates by sampling noise.
    let mut offered_by_client = vec![0u64; CLIENTS];
    for r in report.log.records() {
        offered_by_client[r.client as usize] += 1;
    }
    for (c, n) in &by_client {
        offered_by_client[*c as usize] += n;
    }
    let mut shed_table = Table::new(
        "overload — shed rate per link profile",
        &["link", "clients", "offered", "shed", "shed rate"],
    );
    for (name, lo, hi) in bounds {
        let shed: u64 = by_client
            .iter()
            .filter(|(c, _)| **c >= lo && **c < hi)
            .map(|(_, n)| n)
            .sum();
        let offered: u64 = offered_by_client[lo as usize..hi as usize].iter().sum();
        shed_table.row(vec![
            name.to_string(),
            (hi - lo).to_string(),
            offered.to_string(),
            shed.to_string(),
            format!("{:.3}", shed as f64 / offered.max(1) as f64),
        ]);
    }
    shed_table.print();
    let total_shed: u64 = by_client.values().sum();
    println!(
        "  reconciled: offered {} = completed {} + rejected {} (rejection log {})",
        report.offered, report.completed, report.rejected, total_shed
    );
}
