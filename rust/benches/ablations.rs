//! Ablations over the paper's §5 mitigations and design choices:
//!
//!   A1  sync vs async reduce at/past the latency knee (§3.5 solution 2)
//!   A2  partial-gradient communication: bandwidth vs convergence (§5)
//!   A3  multiple master reduce processes (§3.5 solution 1)
//!   A4  pie-cutter vs naive reallocation: transfer cost on join (§3.3b)
//!
//!     cargo bench --bench ablations             # all four
//!     cargo bench --bench ablations -- --fast   # reduced sweeps

use mlitb::allocation::Allocator;
use mlitb::coordinator::ReducePolicy;
use mlitb::metrics::Table;
use mlitb::model::Manifest;
use mlitb::netsim::MasterModel;
use mlitb::runtime::{Engine, ModeledCompute};
use mlitb::sim::{SimConfig, Simulation};

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let manifest = Manifest::load_default().expect("run `make artifacts`");
    let spec = manifest.model("mnist_conv").unwrap().clone();

    ablation_sync_vs_async(&spec, fast);
    ablation_partial_gradients(fast);
    ablation_master_processes(&spec, fast);
    ablation_pie_cutter(fast);
}

/// A1: the sync barrier stalls the whole fleet on the slowest drain; async
/// closes iterations at T.  Past the knee, async holds power.
fn ablation_sync_vs_async(spec: &mlitb::model::ModelSpec, fast: bool) {
    let nodes = if fast { vec![64] } else { vec![32, 64, 96] };
    let iters = if fast { 8 } else { 20 };
    let mut table = Table::new(
        "A1 — sync vs async reduce (modeled compute)",
        &["nodes", "policy", "power (vec/s)", "s/iter", "mean latency (ms)"],
    );
    for &n in &nodes {
        for policy in [ReducePolicy::Sync, ReducePolicy::Async] {
            let mut cfg = SimConfig::paper_scaling(n, spec);
            cfg.iterations = iters;
            cfg.master.policy = policy;
            cfg.seed = 21;
            let mut compute = ModeledCompute {
                param_count: spec.param_count,
            };
            let mut sim = Simulation::new(cfg, spec.clone(), &mut compute);
            let report = sim.run().unwrap();
            table.row(vec![
                n.to_string(),
                policy.name(),
                format!("{:.0}", report.power_vps),
                format!("{:.2}", report.virtual_secs / iters as f64),
                format!("{:.1}", report.mean_latency_ms),
            ]);
        }
    }
    table.print();
    println!("expected: async keeps s/iter ≈ T past the knee where sync stretches.\n");
}

/// A2: top-|g| partial gradients cut the sync-point bandwidth; convergence
/// degrades gracefully (real gradients, small fleet).
fn ablation_partial_gradients(fast: bool) {
    let mut engine = Engine::from_default_artifacts().unwrap();
    engine.load_model("mnist_mlp").unwrap();
    let spec = engine.spec("mnist_mlp").unwrap().clone();
    let fracs: Vec<f64> = if fast {
        vec![1.0, 0.1]
    } else {
        vec![1.0, 0.5, 0.25, 0.1]
    };
    let iters = if fast { 8 } else { 20 };
    let mut table = Table::new(
        "A2 — partial-gradient communication (real gradients)",
        &["keep", "bytes/iter (KB)", "final loss", "test err"],
    );
    for &f in &fracs {
        let mut cfg = SimConfig::paper_scaling(4, &spec);
        cfg.iterations = iters;
        cfg.train_size = 2_000;
        cfg.test_size = 320;
        cfg.master.capacity = 500;
        cfg.master.learning_rate = 0.05;
        cfg.power_scale = 0.15;
        cfg.track_every = iters;
        cfg.seed = 22;
        cfg.master.policy = if f >= 1.0 {
            ReducePolicy::Sync
        } else {
            ReducePolicy::PartialSync { keep_fraction: f }
        };
        let mut sim = Simulation::new(cfg, spec.clone(), &mut engine);
        let report = sim.run().unwrap();
        let bytes_per_iter =
            report.bytes_up as f64 / iters as f64 / 1024.0;
        let last_loss = report
            .timeline
            .records()
            .iter()
            .rev()
            .find_map(|r| r.loss)
            .unwrap_or(f64::NAN);
        table.row(vec![
            format!("{f:.2}"),
            format!("{bytes_per_iter:.0}"),
            format!("{last_loss:.4}"),
            report
                .final_test_error
                .map_or("-".into(), |e| format!("{e:.4}")),
        ]);
    }
    table.print();
    println!(
        "expected: bytes ∝ 2×keep (sparse entries carry a u32 index per f32 value,\n\
         so keep=0.5 breaks even — the paper's motivation for *informative* selection);\n\
         convergence degrades gracefully as keep shrinks.\n"
    );
}

/// A3: more master reduce processes push the latency knee right.
fn ablation_master_processes(spec: &mlitb::model::ModelSpec, fast: bool) {
    let procs = if fast { vec![1, 4] } else { vec![1, 2, 4] };
    let nodes = 96;
    let iters = if fast { 8 } else { 20 };
    let mut table = Table::new(
        "A3 — master reduce processes at 96 nodes (modeled compute)",
        &["processes", "power (vec/s)", "mean latency (ms)", "s/iter"],
    );
    for &p in &procs {
        let mut cfg = SimConfig::paper_scaling(nodes, spec);
        cfg.iterations = iters;
        cfg.master.master_model = MasterModel {
            processes: p,
            ..Default::default()
        };
        cfg.seed = 23;
        let mut compute = ModeledCompute {
            param_count: spec.param_count,
        };
        let mut sim = Simulation::new(cfg, spec.clone(), &mut compute);
        let report = sim.run().unwrap();
        table.row(vec![
            p.to_string(),
            format!("{:.0}", report.power_vps),
            format!("{:.1}", report.mean_latency_ms),
            format!("{:.2}", report.virtual_secs / iters as f64),
        ]);
    }
    table.print();
    println!("expected: latency at 96 nodes drops ~1/processes (paper's solution 1).\n");
}

/// A4: transfers on the k-th join — pie-cutter O(total/k) vs naive O(total).
fn ablation_pie_cutter(fast: bool) {
    let total = 60_000;
    let joins = if fast { 8 } else { 20 };
    let mut pie = Allocator::new(3000);
    pie.add_data(total);
    let mut naive = Allocator::new(3000);
    naive.add_data(total);
    let mut table = Table::new(
        "A4 — data transfers on the k-th join (60k corpus, cap 3000)",
        &["join #", "pie-cutter moved", "naive moved"],
    );
    for k in 1..=joins as u64 {
        let d_pie = pie.worker_join(k);
        naive.worker_join(k);
        let d_naive = naive.rebalance_naive();
        pie.check_invariants().unwrap();
        naive.check_invariants().unwrap();
        if k <= 4 || k % 4 == 0 {
            table.row(vec![
                k.to_string(),
                d_pie.moved().to_string(),
                d_naive.moved().to_string(),
            ]);
        }
    }
    table.print();
    println!(
        "cumulative transfers: pie-cutter {} vs naive {} ({}x)\n\
         expected: pie moves only the fair share; naive reshuffles ~everything each join.",
        pie.transfer_count(),
        naive.transfer_count(),
        naive.transfer_count() / pie.transfer_count().max(1)
    );
}
