//! Robustness frontier: accuracy under attack, the figure the paper
//! never measured.  Sweeps adversary fraction × aggregation mode on a
//! 10-workstation fleet (drifting modeled compute, SGD) and records the
//! final test error of every cell — the paper's plain mean collapses as
//! the hostile fraction grows while the robust estimators track the
//! clean baseline until the adversaries hold a majority.
//!
//!     cargo bench --bench fig_robust              # full 4×4 sweep
//!     cargo bench --bench fig_robust -- --fast    # 2×4 CI subset
//!     cargo bench --bench fig_robust -- --json out.json
//!
//! Writes `BENCH_robust.json` (one row per cell: fraction, mode,
//! adversaries drawn, final error, quarantined submissions, evictions).

use mlitb::cli::Args;
use mlitb::faults::FaultProfile;
use mlitb::json::{self, Value};
use mlitb::metrics::Table;
use mlitb::model::{ModelSpec, TensorSpec};
use mlitb::params::{AggregationMode, OptimizerKind};
use mlitb::runtime::DriftingCompute;
use mlitb::sim::{SimConfig, Simulation};

const NODES: usize = 10;
const SEED: u64 = 1;

fn toy_spec(param_count: usize) -> ModelSpec {
    ModelSpec {
        name: "toy".into(),
        param_count,
        batch_size: 16,
        micro_batches: vec![16],
        input: vec![28, 28, 1],
        classes: 10,
        tensors: vec![TensorSpec {
            name: "w".into(),
            shape: vec![param_count],
            offset: 0,
            size: param_count,
            fan_in: 4,
        }],
        artifacts: Default::default(),
    }
}

struct Cell {
    fraction: f64,
    mode: String,
    adversaries: usize,
    error: f64,
    quarantined: u64,
    evicted: usize,
}

fn run_cell(spec: &ModelSpec, fraction: f64, mode: AggregationMode, iters: u64) -> Cell {
    let profile = if fraction > 0.0 {
        FaultProfile::parse(&format!("hostile:{fraction}:scaled:-8")).unwrap()
    } else {
        FaultProfile::none()
    };
    let mut cfg = SimConfig::paper_scaling(NODES, spec);
    cfg.train_size = 800;
    cfg.test_size = 64;
    cfg.iterations = iters;
    cfg.master.capacity = 200;
    cfg.master.optimizer = OptimizerKind::Sgd;
    cfg.master.learning_rate = 0.1;
    cfg.master.aggregation = mode;
    cfg.seed = SEED;
    cfg.faults = profile;
    let mut compute = DriftingCompute {
        param_count: spec.param_count,
    };
    let mut sim = Simulation::new(cfg, spec.clone(), &mut compute);
    let adversaries = (1..=NODES as u64)
        .filter(|&w| sim.fault_plan().is_adversary(w))
        .count();
    for _ in 0..iters {
        sim.step().expect("sim step");
    }
    // Quarantine totals live on the master's strike export (scaled
    // corruption stays finite, so most cells quarantine nothing — the
    // NaN/Inf modes are what the sanitation gate catches).
    let strikes = sim.master().export_state().strikes;
    let quarantined: u64 = strikes.iter().map(|&(_, n)| n as u64).sum();
    let evicted = NODES - sim.n_clients();
    let error = sim.evaluate_test_error().expect("eval");
    Cell {
        fraction,
        mode: mode.name(),
        adversaries,
        error,
        quarantined,
        evicted,
    }
}

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast");
    let json_path = args.get_or("json", "BENCH_robust.json").to_string();
    let fractions: Vec<f64> = if fast {
        vec![0.0, 0.3]
    } else {
        vec![0.0, 0.1, 0.3, 0.5]
    };
    let iters: u64 = if fast { 12 } else { 20 };
    let modes = [
        AggregationMode::Mean,
        AggregationMode::TrimmedMean { k: 3 },
        AggregationMode::CoordinateMedian,
        AggregationMode::ClipByNorm { max_norm: 0.5 },
    ];

    let spec = toy_spec(32);
    println!(
        "Fig robust: final test error after {iters} iterations, {NODES} workstations, \
         seed {SEED}\n(adversaries upload gradients scaled by -8; drifting modeled compute)\n"
    );
    let mut table = Table::new(
        "accuracy under attack — final test error by adversary fraction x aggregation",
        &["fraction", "adversaries", "mean", "trimmed:3", "median", "clip:0.5"],
    );
    let mut rows: Vec<Value> = Vec::new();
    for &fraction in &fractions {
        let cells: Vec<Cell> = modes
            .iter()
            .map(|&m| run_cell(&spec, fraction, m, iters))
            .collect();
        table.row(vec![
            format!("{fraction:.1}"),
            cells[0].adversaries.to_string(),
            format!("{:.4}", cells[0].error),
            format!("{:.4}", cells[1].error),
            format!("{:.4}", cells[2].error),
            format!("{:.4}", cells[3].error),
        ]);
        for c in &cells {
            rows.push(json::object(vec![
                ("fraction", Value::Number(c.fraction)),
                ("mode", Value::String(c.mode.clone())),
                ("adversaries", Value::Number(c.adversaries as f64)),
                ("final_error", Value::Number(c.error)),
                ("quarantined", Value::Number(c.quarantined as f64)),
                ("evicted", Value::Number(c.evicted as f64)),
            ]));
        }
        println!("  [fraction {fraction:.1} done]");
    }
    table.print();
    println!(
        "expected shape: the mean column degrades as the fraction grows (sign-flipped\n\
         effective gradient by 0.3); trimmed/median track the clean row until the\n\
         adversaries reach a majority; clip bounds the damage in between."
    );

    let doc = json::object(vec![
        ("nodes", Value::Number(NODES as f64)),
        ("seed", Value::Number(SEED as f64)),
        ("iterations", Value::Number(iters as f64)),
        ("corruption", Value::String("scaled:-8".into())),
        ("fast_mode", Value::Bool(fast)),
        ("cells", Value::Array(rows)),
    ]);
    match std::fs::write(&json_path, json::to_string_pretty(&doc)) {
        Ok(()) => println!("wrote {json_path}"),
        Err(e) => eprintln!("warning: could not write {json_path}: {e}"),
    }
}
