//! Micro benchmarks over the L3 hot paths (and the PJRT execution costs
//! that calibrate the simulation's device model):
//!
//!   * reduce-step kernels: gradient merge (axpy), weighted average,
//!     AdaGrad step — the per-iteration master cost behind the Fig 4 knee
//!   * payload sparsification (partial gradients)
//!   * JSON closure serialize/parse (research-closure cost)
//!   * zip archive build/read + data-server serve
//!   * PJRT grad/eval execution per model (the real per-batch cost)
//!
//!     cargo bench --bench micro             # everything
//!     cargo bench --bench micro -- --fast   # skip PJRT section

use mlitb::bench::{bench, black_box, fmt_ns};
use mlitb::coordinator::Payload;
use mlitb::data::{build_archive, read_archive, DataServer, SynthSpec, Synthesizer};
use mlitb::model::{init_params, Manifest, ResearchClosure};
use mlitb::params::{AdaGrad, GradAccumulator, Optimizer};
use mlitb::rng::Pcg32;
use mlitb::runtime::{BatchBuilder, Engine};

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let manifest = Manifest::load_default().expect("run `make artifacts`");
    let spec = manifest.model("mnist_conv").unwrap().clone();
    let p = spec.param_count;

    println!("== reduce-step kernels ({p} params ≙ mnist_conv) ==");
    let mut rng = Pcg32::new(1);
    let grad: Vec<f32> = (0..p).map(|_| rng.gen_f32() - 0.5).collect();
    let mut acc = GradAccumulator::new(p);
    let r = bench("grad merge (add, 1 worker msg)", 10, 200, || {
        acc.add(&grad, 32);
    });
    println!("{}", r.report());
    println!(
        "    -> {:.2} ns/param (MasterModel.merge_ns_per_param calibration)",
        r.median_ns() / p as f64
    );
    let mut avg = vec![0.0f32; p];
    let r = bench("weighted average (into)", 10, 200, || {
        acc.weighted_average_into(&mut avg);
    });
    println!("{}", r.report());
    let mut opt = AdaGrad::new(p, 0.01, 1e-8);
    let mut params: Vec<f32> = vec![0.0; p];
    let r = bench("AdaGrad step", 10, 200, || {
        opt.step(&mut params, &grad);
    });
    println!("{}", r.report());
    let r = bench("sparsify top-10%", 5, 50, || {
        black_box(Payload::sparsify(&grad, 0.1))
    });
    println!("{}", r.report());

    println!("\n== research closure (JSON) ==");
    let closure = ResearchClosure::new(&spec, &init_params(&spec, 1));
    let r = bench("closure -> JSON string", 3, 20, || {
        black_box(mlitb::json::to_string(&closure.to_json()))
    });
    println!("{}", r.report());
    let text = mlitb::json::to_string(&closure.to_json());
    println!("    closure size: {:.1} KB", text.len() as f64 / 1024.0);
    let r = bench("JSON parse + validate closure", 3, 20, || {
        black_box(ResearchClosure::from_json(&mlitb::json::parse(&text).unwrap()).unwrap())
    });
    println!("{}", r.report());

    println!("\n== data path (zip archives, 100 samples of 28x28) ==");
    let corpus = Synthesizer::new(SynthSpec::mnist(2)).corpus(100);
    let r = bench("build_archive(100)", 2, 10, || {
        black_box(build_archive(&corpus).unwrap())
    });
    println!("{}", r.report());
    let bytes = build_archive(&corpus).unwrap();
    println!("    archive size: {:.1} KB", bytes.len() as f64 / 1024.0);
    let r = bench("read_archive(100)", 2, 10, || {
        black_box(read_archive(&bytes).unwrap())
    });
    println!("{}", r.report());
    let mut server = DataServer::new();
    server.upload_samples(corpus);
    let ids: Vec<u32> = (0..100).collect();
    let r = bench("DataServer::serve(100 ids)", 5, 100, || {
        black_box(server.serve(&ids))
    });
    println!("{}", r.report());

    if fast {
        println!("\n(--fast: skipping PJRT execution benches)");
        return;
    }

    println!("\n== PJRT execution (per microbatch of 32) ==");
    let mut engine = Engine::new(manifest).unwrap();
    let synth = Synthesizer::new(SynthSpec::mnist(3));
    let mnist_samples: Vec<_> = synth.corpus(64).into_iter().map(std::sync::Arc::new).collect();
    let cifar_synth = Synthesizer::new(SynthSpec::cifar(3));
    let cifar_samples: Vec<_> = cifar_synth
        .corpus(64)
        .into_iter()
        .map(std::sync::Arc::new)
        .collect();
    for model in ["mnist_mlp", "mnist_conv", "cifar_conv", "convnet_wide"] {
        engine.load_model(model).unwrap();
        let spec = engine.spec(model).unwrap().clone();
        let params = init_params(&spec, 0);
        let mut batch = BatchBuilder::new(spec.batch_size, spec.input_len());
        let samples = if spec.input == vec![32, 32, 3] {
            &cifar_samples
        } else {
            &mnist_samples
        };
        batch.fill_cyclic(samples, 0);
        let images = batch.images().to_vec();
        let labels = batch.labels().to_vec();
        let r = bench(&format!("{model}: grad batch"), 3, 15, || {
            black_box(engine.grad(model, &params, &images, &labels).unwrap())
        });
        println!("{}", r.report());
        let r = bench(&format!("{model}: eval batch"), 3, 15, || {
            black_box(engine.eval(model, &params, &images, &labels).unwrap())
        });
        println!("{}", r.report());
        let med = r.median_ns();
        println!(
            "    -> {} per vector (eval)",
            fmt_ns(med / spec.batch_size as f64)
        );
    }
}
