//! Micro benchmarks over the L3 hot paths (and the PJRT execution costs
//! that calibrate the simulation's device model):
//!
//!   * reduce-step kernels: single-thread vs parameter-sharded gradient
//!     merge, weighted average, AdaGrad step — the per-iteration master
//!     cost behind the Fig 4 knee.  This section needs no artifacts and
//!     writes `BENCH_reduce.json` (ns/param, sharded speedups, worker
//!     sweep) — the `MasterModel.merge_ns_per_param` calibration source.
//!   * payload sparsification (partial gradients)
//!   * JSON closure serialize/parse (research-closure cost)
//!   * zip archive build/read + data-server serve
//!   * PJRT grad/eval execution per model (the real per-batch cost)
//!
//!     cargo bench --bench micro                    # everything
//!     cargo bench --bench micro -- --fast          # skip PJRT section
//!     cargo bench --bench micro -- --reduce-only   # reduce section only
//!     cargo bench --bench micro -- --reduce-only --check
//!                                                  # CI smoke (few iters)
//!     cargo bench --bench micro -- --json out.json # BENCH_reduce.json path

use std::sync::Arc;

use mlitb::bench::{bench, black_box, fmt_ns};
use mlitb::cli::Args;
use mlitb::coordinator::Payload;
use mlitb::data::{build_archive, read_archive, DataServer, SynthSpec, Synthesizer};
use mlitb::json::{self, Value};
use mlitb::model::{init_params, Manifest, ResearchClosure};
use mlitb::params::{AdaGrad, GradAccumulator, GradView, Optimizer, ShardedAccumulator};
use mlitb::rng::Pcg32;
use mlitb::runtime::{BatchBuilder, Engine};
use mlitb::trace::{ArgValue, TraceHandle, Track};

/// Parameter count for the reduce section: ≥100k, power of two, roughly
/// the paper's "small neural network" gradient (~0.5 MB of f32).
const REDUCE_DIM: usize = 131_072;
/// The paper's knee: 64 near-simultaneous gradient messages.
const REDUCE_SUBS: usize = 64;

fn gen_grads(n: usize, dim: usize, seed: u64) -> Vec<Arc<[f32]>> {
    let mut rng = Pcg32::new(seed);
    (0..n)
        .map(|_| {
            (0..dim)
                .map(|_| rng.gen_f32() - 0.5)
                .collect::<Vec<f32>>()
                .into()
        })
        .collect()
}

/// The reduce-merge section: single-thread reference vs the sharded
/// accumulator, plus a worker-count sweep; records `BENCH_reduce.json`.
fn reduce_bench(check: bool, json_path: &str) {
    let (warm, iters) = if check { (1, 4) } else { (5, 40) };
    println!(
        "== gradient merge ({REDUCE_SUBS} submissions x {REDUCE_DIM} params{}) ==",
        if check { ", --check" } else { "" }
    );
    let grads = gen_grads(REDUCE_SUBS, REDUCE_DIM, 1);
    let work = (REDUCE_DIM * REDUCE_SUBS) as f64;

    let mut single = GradAccumulator::new(REDUCE_DIM);
    let r = bench("merge: single-thread reference", warm, iters, || {
        single.reset();
        for g in &grads {
            single.add(g, 32);
        }
    });
    println!("{}", r.report());
    let single_np = r.median_ns() / work;
    println!(
        "    -> {single_np:.3} ns/param (MasterModel.merge_ns_per_param calibration; \
         inject with --merge-ns)"
    );
    let reference = single.weighted_average();

    let mut sharded_rows: Vec<Value> = Vec::new();
    let mut best_speedup = 0.0f64;
    for shards in [1usize, 2, 4, 8] {
        let mut acc = ShardedAccumulator::new(REDUCE_DIM, shards);
        let batch: Vec<(GradView<'_>, u64)> =
            grads.iter().map(|g| (GradView::Dense(g.as_ref()), 32)).collect();
        let r = bench(&format!("merge: sharded S={shards}"), warm, iters, || {
            acc.reset();
            acc.merge(&batch);
        });
        println!("{}", r.report());
        let np = r.median_ns() / work;
        let speedup = single_np / np;
        best_speedup = best_speedup.max(speedup);
        println!("    -> {np:.3} ns/param, {speedup:.2}x vs single");
        assert_eq!(
            acc.weighted_average(),
            reference,
            "sharded S={shards} must be bitwise-identical to the reference"
        );
        sharded_rows.push(json::object(vec![
            ("shards", Value::Number(shards as f64)),
            ("ns_per_param", Value::Number(np)),
            ("speedup", Value::Number(speedup)),
        ]));
    }

    // Worker-count sweep: how merge throughput scales with burst size
    // (fixed S=4 vs single) — the Fig 4 x-axis seen from the reduce.
    let mut worker_rows: Vec<Value> = Vec::new();
    for workers in [8usize, 16, 32, 64] {
        let sub = &grads[..workers];
        let w_work = (REDUCE_DIM * workers) as f64;
        let mut acc1 = GradAccumulator::new(REDUCE_DIM);
        let r1 = bench(&format!("merge: {workers} workers, single"), warm, iters, || {
            acc1.reset();
            for g in sub {
                acc1.add(g, 32);
            }
        });
        let mut acc4 = ShardedAccumulator::new(REDUCE_DIM, 4);
        let batch: Vec<(GradView<'_>, u64)> =
            sub.iter().map(|g| (GradView::Dense(g.as_ref()), 32)).collect();
        let r4 = bench(&format!("merge: {workers} workers, sharded S=4"), warm, iters, || {
            acc4.reset();
            acc4.merge(&batch);
        });
        println!("{}\n{}", r1.report(), r4.report());
        let np1 = r1.median_ns() / w_work;
        let np4 = r4.median_ns() / w_work;
        worker_rows.push(json::object(vec![
            ("workers", Value::Number(workers as f64)),
            ("single_ns_per_param", Value::Number(np1)),
            ("sharded4_ns_per_param", Value::Number(np4)),
            ("speedup", Value::Number(np1 / np4)),
        ]));
    }

    // Sparse routing: binary-search fan-out of a top-10% payload.
    let Payload::Sparse(entries) = Payload::sparsify(&grads[0], 0.1) else {
        unreachable!()
    };
    let mut acc = ShardedAccumulator::new(REDUCE_DIM, 4);
    let batch: Vec<(GradView<'_>, u64)> = (0..REDUCE_SUBS)
        .map(|_| (GradView::Sparse(&entries), 32))
        .collect();
    let r = bench("merge: sparse top-10% x64, sharded S=4", warm, iters, || {
        acc.reset();
        acc.merge(&batch);
    });
    println!("{}", r.report());

    // Tracer on the merge hot path: the master emits one ingest span per
    // merged submission, so the realistic density is REDUCE_SUBS span
    // attempts per merge.  The disabled handle must be within noise; the
    // recording handle's per-event cost is reported for context.
    let mut acc_t = ShardedAccumulator::new(REDUCE_DIM, 4);
    let batch_t: Vec<(GradView<'_>, u64)> =
        grads.iter().map(|g| (GradView::Dense(g.as_ref()), 32)).collect();
    // Check mode still needs enough iterations for a stable median here —
    // the assertion below compares two timings of the same kernel.
    let (t_warm, t_iters) = if check { (2, 12) } else { (warm, iters) };
    let emit = |trace: &TraceHandle| {
        let track = Track::master(0);
        for k in 0..REDUCE_SUBS as u64 {
            trace.span(
                track,
                "train",
                "ingest",
                k as f64,
                (k + 1) as f64,
                &[("bytes", ArgValue::U64(64))],
            );
        }
    };
    let r_plain = bench("merge: S=4, no tracer", t_warm, t_iters, || {
        acc_t.reset();
        acc_t.merge(&batch_t);
    });
    let off = TraceHandle::off();
    let r_off = bench("merge: S=4, tracer disabled", t_warm, t_iters, || {
        acc_t.reset();
        acc_t.merge(&batch_t);
        emit(&off);
    });
    let on = TraceHandle::with_capacity(1 << 16);
    let r_on = bench("merge: S=4, tracer recording", t_warm, t_iters, || {
        acc_t.reset();
        acc_t.merge(&batch_t);
        emit(&on);
    });
    println!("{}\n{}\n{}", r_plain.report(), r_off.report(), r_on.report());
    let tracer_off_overhead_pct = (r_off.median_ns() / r_plain.median_ns() - 1.0) * 100.0;
    let tracer_on_overhead_pct = (r_on.median_ns() / r_plain.median_ns() - 1.0) * 100.0;
    println!(
        "    -> tracer disabled: {tracer_off_overhead_pct:+.2}% vs plain; \
         recording: {tracer_on_overhead_pct:+.2}%"
    );
    if check {
        assert!(
            tracer_off_overhead_pct < 2.0,
            "disabled tracer must be within noise (<2%), saw {tracer_off_overhead_pct:.2}%"
        );
    }

    let doc = json::object(vec![
        ("params", Value::Number(REDUCE_DIM as f64)),
        ("submissions", Value::Number(REDUCE_SUBS as f64)),
        ("check_mode", Value::Bool(check)),
        ("single_ns_per_param", Value::Number(single_np)),
        // What `--merge-ns` on the sweeps should be fed on this machine.
        ("merge_ns_per_param_calibration", Value::Number(single_np)),
        ("best_sharded_speedup", Value::Number(best_speedup)),
        ("tracer_off_overhead_pct", Value::Number(tracer_off_overhead_pct)),
        ("tracer_on_overhead_pct", Value::Number(tracer_on_overhead_pct)),
        ("sharded", Value::Array(sharded_rows)),
        ("worker_sweep", Value::Array(worker_rows)),
    ]);
    match std::fs::write(json_path, json::to_string_pretty(&doc)) {
        Ok(()) => println!("wrote {json_path} (best sharded speedup {best_speedup:.2}x)"),
        Err(e) => eprintln!("warning: could not write {json_path}: {e}"),
    }
}

/// The durable-plane section: the WAL append must stay a buffered write
/// — the training hot path never fsyncs (syncs happen only at checkpoint
/// boundaries).  Records ns/record for the buffered append beside a
/// per-record-fsync strawman; writes `BENCH_storage.json`.
fn storage_bench(check: bool, json_path: &str) {
    use mlitb::storage::{RunIdentity, WalRecord, WalWriter};
    let (warm, iters) = if check { (1, 4) } else { (3, 20) };
    const BATCH: usize = 256;
    println!(
        "\n== storage (WAL append, {BATCH} records/iter{}) ==",
        if check { ", --check" } else { "" }
    );
    let dir = std::env::temp_dir().join(format!("mlitb-bench-storage-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let identity = RunIdentity { seed: 1, config_digest: 0xBE9C };
    let record = |i: u64| WalRecord {
        iteration: i,
        t_virtual_ms: i as f64 * 4_000.0,
        seed: 1,
        workers: 8,
        worker_set_digest: 0x1234_5678,
        stepped: true,
        grad_digest: 0x9ABC_DEF0,
        params_digest: 0x0FED_CBA9,
    };

    let mut buffered = WalWriter::open(&dir.join("buffered.log"), identity).unwrap();
    let mut n = 0u64;
    let r_buf = bench("wal: buffered append", warm, iters, || {
        for _ in 0..BATCH {
            buffered.append(&record(n)).unwrap();
            n += 1;
        }
    });
    println!("{}", r_buf.report());
    let buf_ns = r_buf.median_ns() / BATCH as f64;
    println!("    -> {buf_ns:.0} ns/record (hot path: no fsync)");

    // The strawman the design rejects: fsync every record.
    let sync_batch = if check { 4usize } else { 32 };
    let mut synced = WalWriter::open(&dir.join("synced.log"), identity).unwrap();
    let mut m = 0u64;
    let r_sync = bench("wal: per-record fsync strawman", warm, iters, || {
        for _ in 0..sync_batch {
            synced.append(&record(m)).unwrap();
            synced.sync().unwrap();
            m += 1;
        }
    });
    println!("{}", r_sync.report());
    let sync_ns = r_sync.median_ns() / sync_batch as f64;
    println!(
        "    -> {sync_ns:.0} ns/record ({:.1}x the buffered append)",
        sync_ns / buf_ns
    );
    if check {
        assert!(
            buf_ns * 3.0 < sync_ns,
            "buffered WAL append must be far cheaper than per-record fsync \
             ({buf_ns:.0} vs {sync_ns:.0} ns/record)"
        );
    }

    let doc = json::object(vec![
        ("records_per_iter", Value::Number(BATCH as f64)),
        ("check_mode", Value::Bool(check)),
        ("append_ns_per_record", Value::Number(buf_ns)),
        ("fsync_ns_per_record", Value::Number(sync_ns)),
        ("fsync_penalty_x", Value::Number(sync_ns / buf_ns)),
    ]);
    match std::fs::write(json_path, json::to_string_pretty(&doc)) {
        Ok(()) => println!("wrote {json_path} (fsync penalty {:.1}x)", sync_ns / buf_ns),
        Err(e) => eprintln!("warning: could not write {json_path}: {e}"),
    }
    drop(buffered);
    drop(synced);
    let _ = std::fs::remove_dir_all(&dir);
}

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast");
    let check = args.flag("check");
    let json_path = args.get_or("json", "BENCH_reduce.json");

    reduce_bench(check, json_path);
    storage_bench(check, args.get_or("storage-json", "BENCH_storage.json"));
    if args.flag("reduce-only") {
        return;
    }

    let manifest = Manifest::load_default().expect("run `make artifacts`");
    let spec = manifest.model("mnist_conv").unwrap().clone();
    let p = spec.param_count;

    println!("\n== reduce-step epilogue ({p} params ≙ mnist_conv) ==");
    let mut rng = Pcg32::new(1);
    let grad: Vec<f32> = (0..p).map(|_| rng.gen_f32() - 0.5).collect();
    let mut acc = GradAccumulator::new(p);
    acc.add(&grad, 32);
    let mut avg = vec![0.0f32; p];
    let r = bench("weighted average (into)", 10, 200, || {
        acc.weighted_average_into(&mut avg);
    });
    println!("{}", r.report());
    let mut opt = AdaGrad::new(p, 0.01, 1e-8);
    let mut params: Vec<f32> = vec![0.0; p];
    let r = bench("AdaGrad step", 10, 200, || {
        opt.step(&mut params, &grad);
    });
    println!("{}", r.report());
    let r = bench("sparsify top-10%", 5, 50, || {
        black_box(Payload::sparsify(&grad, 0.1))
    });
    println!("{}", r.report());

    println!("\n== research closure (JSON) ==");
    let closure = ResearchClosure::new(&spec, &init_params(&spec, 1));
    let r = bench("closure -> JSON string", 3, 20, || {
        black_box(mlitb::json::to_string(&closure.to_json()))
    });
    println!("{}", r.report());
    let text = mlitb::json::to_string(&closure.to_json());
    println!("    closure size: {:.1} KB", text.len() as f64 / 1024.0);
    let r = bench("JSON parse + validate closure", 3, 20, || {
        black_box(ResearchClosure::from_json(&mlitb::json::parse(&text).unwrap()).unwrap())
    });
    println!("{}", r.report());

    println!("\n== data path (zip archives, 100 samples of 28x28) ==");
    let corpus = Synthesizer::new(SynthSpec::mnist(2)).corpus(100);
    let r = bench("build_archive(100)", 2, 10, || {
        black_box(build_archive(&corpus).unwrap())
    });
    println!("{}", r.report());
    let bytes = build_archive(&corpus).unwrap();
    println!("    archive size: {:.1} KB", bytes.len() as f64 / 1024.0);
    let r = bench("read_archive(100)", 2, 10, || {
        black_box(read_archive(&bytes).unwrap())
    });
    println!("{}", r.report());
    let mut server = DataServer::new();
    server.upload_samples(corpus);
    let ids: Vec<u32> = (0..100).collect();
    let r = bench("DataServer::serve(100 ids)", 5, 100, || {
        black_box(server.serve(&ids))
    });
    println!("{}", r.report());

    if fast {
        println!("\n(--fast: skipping PJRT execution benches)");
        return;
    }

    println!("\n== PJRT execution (per microbatch of 32) ==");
    let mut engine = Engine::new(manifest).unwrap();
    let synth = Synthesizer::new(SynthSpec::mnist(3));
    let mnist_samples: Vec<_> = synth.corpus(64).into_iter().map(std::sync::Arc::new).collect();
    let cifar_synth = Synthesizer::new(SynthSpec::cifar(3));
    let cifar_samples: Vec<_> = cifar_synth
        .corpus(64)
        .into_iter()
        .map(std::sync::Arc::new)
        .collect();
    for model in ["mnist_mlp", "mnist_conv", "cifar_conv", "convnet_wide"] {
        engine.load_model(model).unwrap();
        let spec = engine.spec(model).unwrap().clone();
        let params = init_params(&spec, 0);
        let mut batch = BatchBuilder::new(spec.batch_size, spec.input_len());
        let samples = if spec.input == vec![32, 32, 3] {
            &cifar_samples
        } else {
            &mnist_samples
        };
        batch.fill_cyclic(samples, 0);
        let images = batch.images().to_vec();
        let labels = batch.labels().to_vec();
        let r = bench(&format!("{model}: grad batch"), 3, 15, || {
            black_box(engine.grad(model, &params, &images, &labels).unwrap())
        });
        println!("{}", r.report());
        let r = bench(&format!("{model}: eval batch"), 3, 15, || {
            black_box(engine.eval(model, &params, &images, &labels).unwrap())
        });
        println!("{}", r.report());
        let med = r.median_ns();
        println!(
            "    -> {} per vector (eval)",
            fmt_ns(med / spec.batch_size as f64)
        );
    }
}
