"""L2: MLitB neural-network models in JAX, calling the L1 Pallas kernels.

The paper's use-case model (§3.5, footnote 6) is a convolutional NN:
``28×28 input → 16 conv filters (5×5, with 2×2 pooling) → fully-connected
softmax output``.  We implement that exactly (``mnist_conv``), plus the
CIFAR-shaped variant used by the tracking-mode experiment (``cifar_conv``,
Figs 6–8), an MLP (``mnist_mlp``, the "without convolutions" configuration
§3.7 measures on mobile devices), and a wider extension model.

Design decisions shared with the Rust L3 layer:

* **Flat parameter vector.**  All parameters live in one f32 vector, packed
  in declaration order.  The paper broadcasts "an array of model
  parameters" (§3.3e) and the reduce step sums gradient arrays — a flat
  vector makes the Rust-side reduce/AdaGrad a dense axpy loop and the
  research closure a single JSON array.  ``unpack`` slices are static, so
  XLA fuses them away.
* **Sum (not mean) losses.**  ``grad`` returns the *sum* of per-example
  gradient contributions plus the example count; the master computes the
  weighted average across heterogeneous client batch counts (§3.6
  "weighted average of gradients from all workers").
* **Fixed microbatch.**  Artifacts are compiled for a fixed batch B; a
  client runs as many microbatches as fit its time budget (§3.3d: clients
  have no batch size, they clock their own computation).

Layer-spec schema (mirrored by ``rust/src/model``):
    {"type": "conv",  "kh": 5, "kw": 5, "filters": 16}
    {"type": "relu"} | {"type": "pool2"} | {"type": "flatten"}
    {"type": "fc",   "units": 10}
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels import conv2d, matmul, maxpool2

# --------------------------------------------------------------------------
# Model zoo (paper §2.3 "model zoos"): name -> (input shape, classes, layers)
# --------------------------------------------------------------------------

MODELS = {
    # The paper's scaling-experiment network (§3.5 footnote 6).
    "mnist_conv": {
        "input": (28, 28, 1),
        "classes": 10,
        "layers": [
            {"type": "conv", "kh": 5, "kw": 5, "filters": 16},
            {"type": "relu"},
            {"type": "pool2"},
            {"type": "flatten"},
            {"type": "fc", "units": 10},
        ],
    },
    # The tracking-mode CIFAR-10 network (Figs 6-8).
    "cifar_conv": {
        "input": (32, 32, 3),
        "classes": 10,
        "layers": [
            {"type": "conv", "kh": 5, "kw": 5, "filters": 16},
            {"type": "relu"},
            {"type": "pool2"},
            {"type": "flatten"},
            {"type": "fc", "units": 10},
        ],
    },
    # "Without convolutions" mobile configuration (§3.7).
    "mnist_mlp": {
        "input": (28, 28, 1),
        "classes": 10,
        "layers": [
            {"type": "flatten"},
            {"type": "fc", "units": 128},
            {"type": "relu"},
            {"type": "fc", "units": 10},
        ],
    },
    # Extension: a deeper net exercising stacked conv + wider FC, used by
    # the bandwidth/partial-gradient ablations (bigger parameter vector).
    "convnet_wide": {
        "input": (28, 28, 1),
        "classes": 10,
        "layers": [
            {"type": "conv", "kh": 5, "kw": 5, "filters": 16},
            {"type": "relu"},
            {"type": "pool2"},
            {"type": "conv", "kh": 3, "kw": 3, "filters": 32},
            {"type": "relu"},
            {"type": "pool2"},
            {"type": "flatten"},
            {"type": "fc", "units": 64},
            {"type": "relu"},
            {"type": "fc", "units": 10},
        ],
    },
}

DEFAULT_BATCH = 32


@dataclass
class TensorSpec:
    """One parameter tensor inside the flat vector."""

    name: str
    shape: tuple
    offset: int
    fan_in: int  # for init scaling on the Rust side

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n


@dataclass
class ModelDef:
    """A fully-resolved model: layer specs + parameter layout."""

    name: str
    input_shape: tuple
    classes: int
    layers: list
    tensors: list = field(default_factory=list)

    @property
    def param_count(self) -> int:
        return sum(t.size for t in self.tensors)


def build(name: str) -> ModelDef:
    """Resolve a model-zoo entry into a ModelDef with parameter layout."""
    cfg = MODELS[name]
    m = ModelDef(
        name=name,
        input_shape=tuple(cfg["input"]),
        classes=cfg["classes"],
        layers=cfg["layers"],
    )
    h, w, c = m.input_shape
    offset = 0
    flat = None
    for i, layer in enumerate(m.layers):
        t = layer["type"]
        if t == "conv":
            kh, kw, f = layer["kh"], layer["kw"], layer["filters"]
            fan_in = kh * kw * c
            for suffix, shape in (("w", (kh, kw, c, f)), ("b", (f,))):
                ts = TensorSpec(f"l{i}_conv_{suffix}", shape, offset, fan_in)
                m.tensors.append(ts)
                offset += ts.size
            h, w, c = h - kh + 1, w - kw + 1, f
        elif t == "pool2":
            assert h % 2 == 0 and w % 2 == 0, f"pool2 needs even dims, got {h}x{w}"
            h, w = h // 2, w // 2
        elif t == "flatten":
            flat = h * w * c
        elif t == "fc":
            assert flat is not None, "fc requires a preceding flatten"
            units = layer["units"]
            for suffix, shape in (("w", (flat, units)), ("b", (units,))):
                ts = TensorSpec(f"l{i}_fc_{suffix}", shape, offset, flat)
                m.tensors.append(ts)
                offset += ts.size
            flat = units
        elif t == "relu":
            pass
        else:
            raise ValueError(f"unknown layer type {t!r}")
    assert flat == m.classes, f"{name}: final width {flat} != classes {m.classes}"
    return m


def unpack(m: ModelDef, flat):
    """Flat f32 vector -> dict of named parameter tensors (static slices)."""
    out = {}
    for t in m.tensors:
        out[t.name] = jax.lax.slice(flat, (t.offset,), (t.offset + t.size,)).reshape(
            t.shape
        )
    return out


def forward(m: ModelDef, flat, x):
    """Forward pass: NHWC batch -> logits [B, classes].

    Conv and FC contractions run on the L1 Pallas matmul kernel.
    """
    p = unpack(m, flat)
    act = x
    feat = None  # flattened activation once past `flatten`
    for i, layer in enumerate(m.layers):
        t = layer["type"]
        if t == "conv":
            act = conv2d(act, p[f"l{i}_conv_w"], p[f"l{i}_conv_b"])
        elif t == "relu":
            if feat is None:
                act = jnp.maximum(act, 0.0)
            else:
                feat = jnp.maximum(feat, 0.0)
        elif t == "pool2":
            act = maxpool2(act)
        elif t == "flatten":
            feat = act.reshape(act.shape[0], -1)
        elif t == "fc":
            feat = matmul(feat, p[f"l{i}_fc_w"]) + p[f"l{i}_fc_b"]
    return feat


def loss_and_stats(m: ModelDef, flat, x, y):
    """Softmax cross-entropy.

    Returns ``(loss_sum, correct)`` — *sums* over the batch so the master's
    reduce step can weight heterogeneous client contributions by count.
    """
    logits = forward(m, flat, x)
    logz = jax.nn.logsumexp(logits, axis=1)
    picked = jnp.take_along_axis(logits, y[:, None], axis=1)[:, 0]
    loss_sum = jnp.sum(logz - picked)
    correct = jnp.sum((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
    return loss_sum, correct


def make_grad_fn(m: ModelDef):
    """(flat, x, y) -> (grad_flat, loss_sum, correct).  All f32."""

    def loss_fn(flat, x, y):
        loss_sum, correct = loss_and_stats(m, flat, x, y)
        return loss_sum, correct

    def grad_fn(flat, x, y):
        (loss_sum, correct), g = jax.value_and_grad(loss_fn, has_aux=True)(
            flat, x, y
        )
        return g, loss_sum, correct

    return grad_fn


def make_eval_fn(m: ModelDef):
    """(flat, x, y) -> (loss_sum, correct)."""

    def eval_fn(flat, x, y):
        return loss_and_stats(m, flat, x, y)

    return eval_fn


def make_predict_fn(m: ModelDef):
    """(flat, x) -> class probabilities [B, classes]."""

    def predict_fn(flat, x):
        return (jax.nn.softmax(forward(m, flat, x), axis=1),)

    return predict_fn


def init_params(m: ModelDef, seed: int = 0):
    """Reference initializer (LeCun normal for weights, zero biases).

    The Rust side re-implements this layout-compatibly from the manifest
    (same fan-in scaling); this version backs the python tests.
    """
    key = jax.random.PRNGKey(seed)
    chunks = []
    for t in m.tensors:
        key, sub = jax.random.split(key)
        if t.name.endswith("_b"):
            chunks.append(jnp.zeros((t.size,), jnp.float32))
        else:
            scale = 1.0 / jnp.sqrt(float(t.fan_in))
            chunks.append(
                jax.random.normal(sub, (t.size,), jnp.float32) * scale
            )
    return jnp.concatenate(chunks)
