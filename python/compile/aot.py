"""AOT compile path: lower the L2 models to HLO *text* + manifest.json.

This is the only Python that ever runs; ``make artifacts`` invokes it once
and the Rust binary is self-contained afterwards.  Interchange is HLO text,
NOT a serialized HloModuleProto: jax ≥ 0.5 emits protos with 64-bit
instruction ids that xla_extension 0.5.1 (what the published ``xla`` crate
binds) rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
and round-trips cleanly.  See /opt/xla-example/README.md.

Artifacts per model variant (batch size fixed at compile time):
    grad_<name>.hlo.txt     (flat[P], x[B,H,W,C], y[B] i32) -> (g[P], loss_sum, correct)
    eval_<name>.hlo.txt     (flat[P], x, y)                 -> (loss_sum, correct)
    predict_<name>.hlo.txt  (flat[P], x)                    -> (probs[B,classes],)
plus ``manifest.json`` describing shapes, parameter layout and fan-in so the
Rust side can allocate, initialize, and marshal buffers without Python.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(m: M.ModelDef, batch: int):
    """Lower grad/eval/predict for one model; return {kind: hlo_text}."""
    h, w, c = m.input_shape
    flat_spec = jax.ShapeDtypeStruct((m.param_count,), jnp.float32)
    x_spec = jax.ShapeDtypeStruct((batch, h, w, c), jnp.float32)
    y_spec = jax.ShapeDtypeStruct((batch,), jnp.int32)

    grad_fn = M.make_grad_fn(m)
    eval_fn = M.make_eval_fn(m)
    pred_fn = M.make_predict_fn(m)

    return {
        "grad": to_hlo_text(jax.jit(grad_fn).lower(flat_spec, x_spec, y_spec)),
        "eval": to_hlo_text(jax.jit(eval_fn).lower(flat_spec, x_spec, y_spec)),
        "predict": to_hlo_text(jax.jit(pred_fn).lower(flat_spec, x_spec)),
    }


# Extra microbatch sizes compiled for grad/eval: heterogeneous devices pick
# their work quantum (§3.3d — the paper's mobiles compute "only a few
# gradients per second"; a B=32-only artifact would force 16 s of compute
# on them and blow the sync barrier).
MICRO_BATCHES = [8, 1]


def emit(out_dir: str, names, batch: int) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": 1, "batch_size": batch, "models": {}}
    for name in names:
        m = M.build(name)
        entry = {
            "param_count": m.param_count,
            "batch_size": batch,
            "micro_batches": [batch] + MICRO_BATCHES,
            "input": list(m.input_shape),
            "classes": m.classes,
            "layers": m.layers,
            "tensors": [
                {
                    "name": t.name,
                    "shape": list(t.shape),
                    "offset": t.offset,
                    "size": t.size,
                    "fan_in": t.fan_in,
                }
                for t in m.tensors
            ],
            "artifacts": {},
        }

        def write_artifact(kind_key: str, text: str):
            fname = f"{kind_key}_{name}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            entry["artifacts"][kind_key] = {
                "file": fname,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "bytes": len(text),
            }
            print(f"  wrote {fname}: {len(text)} chars")

        for kind, text in lower_model(m, batch).items():
            write_artifact(kind, text)
        for b in MICRO_BATCHES:
            arts = lower_model(m, b)
            # predict_b{n} serves the micro-batching prediction endpoint
            # (rust serve::BatchExecutor) the same way grad_b{n}/eval_b{n}
            # serve weak trainers.
            for kind in ("grad", "eval", "predict"):
                write_artifact(f"{kind}_b{b}", arts[kind])
        manifest["models"][name] = entry
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(
        f"wrote manifest.json ({len(names)} models, batches {[batch] + MICRO_BATCHES})"
    )
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description="MLitB AOT artifact builder")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=M.DEFAULT_BATCH)
    ap.add_argument(
        "--models",
        nargs="*",
        default=list(M.MODELS.keys()),
        choices=list(M.MODELS.keys()),
    )
    args = ap.parse_args()
    emit(args.out_dir, args.models, args.batch)


if __name__ == "__main__":
    main()
