"""L1: Pallas kernels for the MLitB compute hot-spot (matmul / im2col conv).

All kernels use ``interpret=True`` so the lowered HLO runs on the CPU PJRT
client that the Rust runtime drives; see DESIGN.md §Hardware-Adaptation.
"""

from .conv2d import conv2d, maxpool2
from .matmul import matmul

__all__ = ["matmul", "conv2d", "maxpool2"]
