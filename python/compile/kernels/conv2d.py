"""L1 conv2d: im2col + the Pallas matmul kernel.

The paper's use-case NN is ``28×28 input → 16 conv filters (with pooling) →
fully-connected output`` (§3.5 footnote 6).  The paper found that "naive
convolution implementations significantly slow performance" (§3.7); the
classical fix — and the one GPU/TPU libraries use — is lowering convolution
to a matrix product over extracted patches (im2col), which maps the hot
loop onto the systolic-array matmul of ``matmul.py``.

Patch extraction itself is plain JAX (cheap data movement, differentiable
through the standard transpose rule); every FLOP-heavy contraction goes
through the Pallas kernel, forward and backward.
"""

import jax
import jax.numpy as jnp

from .matmul import matmul


def _extract_patches(x, kh: int, kw: int):
    """NHWC ``x`` → patches ``[B, H-kh+1, W-kw+1, kh*kw*C]`` (VALID, stride 1).

    Implemented as a stack of shifted slices: for the 5×5 kernels used here
    that is 25 static slices, which XLA fuses into a single gather-free
    loop nest — considerably cheaper than a general gather.
    """
    b, h, w, c = x.shape
    oh, ow = h - kh + 1, w - kw + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(x[:, i : i + oh, j : j + ow, :])
    # [B, OH, OW, kh*kw, C] -> [B, OH, OW, kh*kw*C]
    patches = jnp.stack(cols, axis=3)
    return patches.reshape(b, oh, ow, kh * kw * c)


def conv2d(x, w, b):
    """VALID 2-D convolution, stride 1, NHWC × HWIO → NHWC.

    ``x``: [B, H, W, C]; ``w``: [KH, KW, C, F]; ``b``: [F].
    All contraction FLOPs run on the Pallas matmul kernel.
    """
    kh, kw, c, f = w.shape
    patches = _extract_patches(x, kh, kw)
    bsz, oh, ow, k = patches.shape
    out = matmul(patches.reshape(bsz * oh * ow, k), w.reshape(kh * kw * c, f))
    return out.reshape(bsz, oh, ow, f) + b


def maxpool2(x):
    """2×2 max pooling, stride 2, NHWC.  H and W must be even."""
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))
