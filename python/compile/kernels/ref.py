"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package must agree with its reference here to within
float32 tolerance; ``python/tests/test_kernel.py`` sweeps shapes and dtypes
with hypothesis and asserts ``allclose``.  These references are also the
"naive implementation" baseline the paper complains about in §3.7 — the
micro benches compare kernel-vs-ref structure.
"""

import jax
import jax.numpy as jnp


def matmul_ref(x, w):
    """Plain jnp matmul in f32."""
    return jnp.matmul(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def conv2d_ref(x, w, b):
    """VALID stride-1 NHWC conv via lax.conv_general_dilated."""
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + b


def maxpool2_ref(x):
    """2×2/2 max pool via reduce_window."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )
