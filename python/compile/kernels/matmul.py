"""L1 Pallas matmul kernel — the compute hot spot of MLitB's neural nets.

The paper's §3.7 notes that "naive convolution implementations significantly
slow performance" and §5 calls for near-native kernels.  This is the TPU
rethink: a tiled matmul targeting the MXU systolic array, with BlockSpecs
expressing the HBM→VMEM schedule.  It is used by both the fully-connected
layers and the im2col formulation of the convolutional layers (see
``conv2d.py``), in the forward *and* backward pass (via ``jax.custom_vjp``:
Pallas calls are not auto-differentiable, so the VJP is written explicitly
in terms of the same kernel).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO so the same
artifact runs under the Rust runtime.  Real-TPU perf is *estimated* from the
BlockSpec tiling in DESIGN.md §Perf.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile-size policy.  The K dimension is kept whole per block (our K's are
# 16–25k floats), M is tiled only when it must be.  Two constraints:
#   * VMEM: x-block (bm×K) + w-block (K×bn) + accumulator (bm×bn) must fit
#     the ~16 MB VMEM budget — we allow the x-block up to 8 MB.
#   * Grid size: every grid step is one HBM→VMEM round trip (and, under
#     interpret=True, one dispatched outer-loop iteration — measured at
#     ~2.3 ms/step on the CPU path, see EXPERIMENTS.md §Perf).  So the
#     policy is: the largest M-block that fits the VMEM budget, i.e.
#     grid=1 for every shape in the model zoo (the biggest, the CIFAR
#     im2col at 25 088×75 f32, is a 7.5 MB block).  Tiling kicks in
#     automatically beyond the budget.
BLOCK_N = 128
VMEM_X_BUDGET = 8 << 20  # bytes for the x-block


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pick_block_m(m: int, k: int) -> int:
    """Largest M-block meeting the VMEM budget, 8-row sublane aligned."""
    cap_vmem = max(8, (VMEM_X_BUDGET // 4) // max(k, 1))
    bm = min(m, cap_vmem)
    return max(8, _cdiv(bm, 8) * 8)


def _mm_kernel(x_ref, w_ref, o_ref):
    """One (BLOCK_M, K) × (K, BLOCK_N) tile product on the MXU.

    ``preferred_element_type=float32`` pins the MXU accumulator to f32
    regardless of input dtype (bf16 inputs would still accumulate in f32).
    """
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@partial(jax.jit, static_argnames=("block_m", "block_n"))
def _matmul_impl(x, w, block_m: int | None = None, block_n: int = BLOCK_N):
    """Tiled Pallas matmul: (M, K) @ (K, N) -> (M, N) in f32.

    M and N are padded up to tile multiples (Pallas masking of partial
    blocks is backend-dependent; explicit zero-padding is deterministic
    and the pad/slice fuses away in XLA).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"matmul inner dims mismatch: {k} vs {k2}"
    bm = block_m if block_m is not None else pick_block_m(m, k)
    bm = min(bm, _cdiv(m, 8) * 8)
    bn = min(block_n, n) if n < block_n else block_n
    mp = _cdiv(m, bm) * bm
    np_ = _cdiv(n, bn) * bn
    xp = jnp.pad(x, ((0, mp - m), (0, 0))) if mp != m else x
    wp = jnp.pad(w, ((0, 0), (0, np_ - n))) if np_ != n else w
    out = pl.pallas_call(
        _mm_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    if mp != m or np_ != n:
        out = out[:m, :n]
    return out


@jax.custom_vjp
def matmul(x, w):
    """Differentiable Pallas matmul (f32): ``x @ w``.

    Forward and both cotangent products run through the same tiled kernel,
    so the backward pass is Pallas-accelerated too:
        dX = dY @ Wᵀ,  dW = Xᵀ @ dY.
    """
    return _matmul_impl(x, w)


def _matmul_fwd(x, w):
    return _matmul_impl(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    return _matmul_impl(g, w.T), _matmul_impl(x.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)
