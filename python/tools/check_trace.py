#!/usr/bin/env python3
"""Validate a Chrome/Perfetto trace-event JSON file emitted by `mlitb --trace`.

Stdlib-only schema + invariant checker, used as the CI gate on the cosim
smoke's trace artifact:

  python3 python/tools/check_trace.py cosim_trace.json

Checks, in order:
  * document shape: ``displayTimeUnit == "ms"``, non-empty ``traceEvents``
  * per event: known phase, integer pid/tid, numeric ts >= 0 (except
    metadata), spans carry a non-negative ``dur``
  * nestable-async balance: every ``b`` has a matching ``e`` per
    (pid, cat, id), ids open at most once at a time
  * flows: every ``f`` names an earlier ``s`` with the same (cat, id) and
    carries binding point ``bp == "e"``
  * counters: every ``C`` carries a non-empty numeric ``args`` series, and
    per (pid, tid, name) the sample timestamps are monotone non-decreasing
  * plane coverage: at least one train-iteration span, one request
    lifecycle, and one publication span are present (the cosim smoke
    exercises all three planes), plus counter tracks from every plane
    (``serve/``, ``train/``, ``publish/`` name prefixes)

Exit code 0 on success; prints the first failure and exits 1 otherwise.
"""

import json
import sys

PHASES = {"X", "b", "e", "i", "s", "f", "M", "C"}
COUNTER_PLANES = ("serve/", "train/", "publish/")


def fail(msg):
    print(f"check_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check(path):
    with open(path, "r", encoding="utf-8") as fh:
        doc = json.load(fh)

    if doc.get("displayTimeUnit") != "ms":
        fail(f"displayTimeUnit must be 'ms', got {doc.get('displayTimeUnit')!r}")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail("traceEvents must be a non-empty array")

    open_async = {}  # (pid, cat, id) -> open count
    flow_started = set()  # (cat, id)
    counter_last = {}  # (pid, tid, name) -> last ts
    counter_planes = set()  # name prefixes seen on counter tracks
    seen = {"train_iteration": False, "request": False, "publish": False}

    for i, e in enumerate(events):
        where = f"event {i}"
        ph = e.get("ph")
        if ph not in PHASES:
            fail(f"{where}: unknown phase {ph!r}")
        if not isinstance(e.get("pid"), (int, float)) or e["pid"] != int(e["pid"]):
            fail(f"{where}: pid must be an integer, got {e.get('pid')!r}")
        if not isinstance(e.get("tid"), (int, float)) or e["tid"] != int(e["tid"]):
            fail(f"{where}: tid must be an integer, got {e.get('tid')!r}")
        if ph == "M":
            if e.get("name") not in ("process_name", "thread_name"):
                fail(f"{where}: unexpected metadata {e.get('name')!r}")
            continue

        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            fail(f"{where}: ts must be a number >= 0, got {ts!r}")
        cat, name = e.get("cat"), e.get("name")
        if not cat or not name:
            fail(f"{where}: data events need cat and name")

        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                fail(f"{where}: span dur must be a number >= 0, got {dur!r}")
            if cat == "train" and name == "iteration":
                seen["train_iteration"] = True
            if cat == "publish" and name == "publish":
                seen["publish"] = True
        elif ph in ("b", "e"):
            key = (int(e["pid"]), cat, e.get("id"))
            if key[2] is None:
                fail(f"{where}: async event without id")
            if ph == "b":
                if open_async.get(key, 0) != 0:
                    fail(f"{where}: async id {key} opened twice")
                open_async[key] = 1
            else:
                if open_async.get(key, 0) != 1:
                    fail(f"{where}: async end without open begin for {key}")
                open_async[key] = 0
            if name == "request":
                seen["request"] = True
        elif ph == "s":
            flow_started.add((cat, e.get("id")))
        elif ph == "f":
            if e.get("bp") != "e":
                fail(f"{where}: flow finish must bind with bp='e'")
            if (cat, e.get("id")) not in flow_started:
                fail(f"{where}: flow finish without a start for (cat={cat}, id={e.get('id')})")
        elif ph == "i":
            if e.get("s") != "t":
                fail(f"{where}: instant scope must be 't'")
        elif ph == "C":
            args = e.get("args")
            if not isinstance(args, dict) or not args:
                fail(f"{where}: counter must carry a non-empty args object")
            for k, v in args.items():
                if not isinstance(v, (int, float)) or isinstance(v, bool):
                    fail(f"{where}: counter series {k!r} must be numeric, got {v!r}")
            key = (int(e["pid"]), int(e["tid"]), name)
            if ts < counter_last.get(key, float("-inf")):
                fail(f"{where}: counter {name!r} timestamps run backwards on {key}")
            counter_last[key] = ts
            for prefix in COUNTER_PLANES:
                if name.startswith(prefix):
                    counter_planes.add(prefix)

    dangling = [k for k, n in open_async.items() if n != 0]
    if dangling:
        fail(f"{len(dangling)} async span(s) never closed, e.g. {dangling[0]}")
    for plane, ok in seen.items():
        if not ok:
            fail(f"no {plane} events — a cosim trace must cover all planes")
    if counter_last:  # counter coverage only binds when counters exist
        missing = [p for p in COUNTER_PLANES if p not in counter_planes]
        if missing:
            fail(f"counter tracks missing for plane prefix(es): {missing}")

    n = len(events)
    print(
        f"check_trace: OK: {path} ({n} events, {len(flow_started)} flow(s), "
        f"{len(counter_last)} counter track(s))"
    )


if __name__ == "__main__":
    if len(sys.argv) != 2:
        print("usage: check_trace.py <trace.json>", file=sys.stderr)
        sys.exit(2)
    check(sys.argv[1])
