"""L2 model correctness: layouts, shapes, gradients, and trainability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


# ------------------------------------------------------------- layout ----


@pytest.mark.parametrize("name", list(M.MODELS.keys()))
def test_param_layout_contiguous(name):
    m = M.build(name)
    offset = 0
    for t in m.tensors:
        assert t.offset == offset, f"{t.name} offset gap"
        offset += t.size
    assert m.param_count == offset


def test_paper_model_param_count():
    """mnist_conv: conv 5*5*1*16+16 = 416, fc 12*12*16*10+10 = 23050."""
    m = M.build("mnist_conv")
    assert m.param_count == 416 + 23050


def test_cifar_model_param_count():
    m = M.build("cifar_conv")
    # conv 5*5*3*16+16 = 1216 ; fc 14*14*16*10+10 = 31370
    assert m.param_count == 1216 + 31370


def test_unpack_roundtrip():
    m = M.build("mnist_mlp")
    flat = jnp.arange(m.param_count, dtype=jnp.float32)
    parts = M.unpack(m, flat)
    rebuilt = jnp.concatenate(
        [parts[t.name].reshape(-1) for t in m.tensors]
    )
    np.testing.assert_array_equal(rebuilt, flat)


# ------------------------------------------------------------- forward ---


@pytest.mark.parametrize("name", list(M.MODELS.keys()))
def test_forward_shapes(name):
    m = M.build(name)
    flat = M.init_params(m, seed=0)
    h, w, c = m.input_shape
    x = jax.random.normal(jax.random.PRNGKey(1), (4, h, w, c))
    logits = M.forward(m, flat, x)
    assert logits.shape == (4, m.classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_predict_probabilities_normalized():
    m = M.build("mnist_conv")
    flat = M.init_params(m, seed=3)
    x = jax.random.normal(jax.random.PRNGKey(2), (5, 28, 28, 1))
    (probs,) = M.make_predict_fn(m)(flat, x)
    np.testing.assert_allclose(jnp.sum(probs, axis=1), jnp.ones(5), rtol=1e-5)
    assert bool(jnp.all(probs >= 0))


def test_loss_at_init_near_log_classes():
    """Random init → uniform-ish predictions → loss ≈ ln(10) per example."""
    m = M.build("mnist_mlp")
    flat = M.init_params(m, seed=0)
    x = jax.random.normal(jax.random.PRNGKey(4), (64, 28, 28, 1)) * 0.1
    y = jnp.zeros((64,), jnp.int32)
    loss_sum, _ = M.loss_and_stats(m, flat, x, y)
    per_ex = float(loss_sum) / 64
    assert abs(per_ex - np.log(10)) < 0.5


# ------------------------------------------------------------ gradients --


def test_grad_matches_finite_difference():
    m = M.build("mnist_mlp")
    flat = M.init_params(m, seed=1)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 28, 28, 1))
    y = jnp.array([3, 7], jnp.int32)
    g, loss_sum, _ = M.make_grad_fn(m)(flat, x, y)
    # probe a few coordinates with central differences
    rng = np.random.RandomState(0)
    idxs = rng.randint(0, m.param_count, size=6)
    eps = 1e-3
    for i in idxs:
        e = jnp.zeros_like(flat).at[i].set(eps)
        lp, _ = M.loss_and_stats(m, flat + e, x, y)
        lm, _ = M.loss_and_stats(m, flat - e, x, y)
        fd = (float(lp) - float(lm)) / (2 * eps)
        assert abs(fd - float(g[i])) < 5e-2 * max(1.0, abs(fd)), (
            f"coord {i}: fd={fd} grad={float(g[i])}"
        )


def test_grad_is_sum_over_batch():
    """grad(batch) == grad(ex0) + grad(ex1): reduce-step weighting relies on it."""
    m = M.build("mnist_mlp")
    flat = M.init_params(m, seed=2)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 28, 28, 1))
    y = jnp.array([1, 8], jnp.int32)
    gfn = M.make_grad_fn(m)
    g_both, loss_both, _ = gfn(flat, x, y)
    g0, l0, _ = gfn(flat, x[:1], y[:1])
    g1, l1, _ = gfn(flat, x[1:], y[1:])
    np.testing.assert_allclose(g_both, g0 + g1, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(loss_both), float(l0) + float(l1), rtol=1e-5)


# ---------------------------------------------------------- trainability --


@pytest.mark.parametrize("name", ["mnist_mlp", "mnist_conv"])
def test_sgd_reduces_loss(name):
    """A few plain-SGD steps on a fixed batch must reduce the loss."""
    m = M.build(name)
    flat = M.init_params(m, seed=0)
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (16,) + m.input_shape) * 0.5
    y = jax.random.randint(jax.random.PRNGKey(8), (16,), 0, m.classes)
    gfn = jax.jit(M.make_grad_fn(m))
    loss0 = None
    for step in range(8):
        g, loss_sum, _ = gfn(flat, x, y)
        if loss0 is None:
            loss0 = float(loss_sum)
        flat = flat - 0.05 * g / 16.0
    lossN, _ = M.loss_and_stats(m, flat, x, y)
    assert float(lossN) < loss0 * 0.9, f"{loss0} -> {float(lossN)}"
