"""AOT path: lowering to HLO text + manifest schema.

These guard the L2→L3 contract: if lowering or the manifest drift, the
Rust runtime fails at artifact load — catch it here first.
"""

import json

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def test_hlo_text_lowering_smoke():
    m = M.build("mnist_mlp")
    arts = aot.lower_model(m, batch=4)
    assert set(arts) == {"grad", "eval", "predict"}
    for kind, text in arts.items():
        assert text.startswith("HloModule"), f"{kind} not HLO text"
        assert "ENTRY" in text
        # 64-bit-id proto issue is avoided by text interchange; make sure
        # nothing serialized binary protos by accident
        assert "\x00" not in text


def test_grad_artifact_has_expected_parameter_shapes():
    m = M.build("mnist_mlp")
    text = aot.lower_model(m, batch=4)["grad"]
    # flat params f32[P], images f32[4,28,28,1], labels s32[4]
    assert f"f32[{m.param_count}]" in text
    assert "f32[4,28,28,1]" in text
    assert "s32[4]" in text


def test_emit_writes_manifest_and_artifacts(tmp_path):
    out = str(tmp_path)
    manifest = aot.emit(out, ["mnist_mlp"], batch=4)
    with open(f"{out}/manifest.json") as f:
        on_disk = json.load(f)
    assert on_disk == manifest
    entry = manifest["models"]["mnist_mlp"]
    assert entry["param_count"] == M.build("mnist_mlp").param_count
    assert entry["micro_batches"] == [4] + aot.MICRO_BATCHES
    # all artifact files exist, with microbatch variants for grad/eval
    kinds = set(entry["artifacts"])
    assert {"grad", "eval", "predict"} <= kinds
    for b in aot.MICRO_BATCHES:
        assert f"grad_b{b}" in kinds
        assert f"eval_b{b}" in kinds
        # serving's micro-batch executor keys predict the same way
        assert f"predict_b{b}" in kinds
    for art in entry["artifacts"].values():
        assert (tmp_path / art["file"]).exists()
        assert art["bytes"] > 0


def test_microbatch_variants_agree_numerically():
    """grad at B=1 summed over a batch == grad at B=n on the same batch."""
    m = M.build("mnist_mlp")
    flat = M.init_params(m, seed=0)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 28, 28, 1))
    y = jnp.array([1, 2, 3, 4], jnp.int32)
    gfn = M.make_grad_fn(m)
    g_full, loss_full, _ = gfn(flat, x, y)
    g_sum = jnp.zeros_like(flat)
    loss_sum = 0.0
    for i in range(4):
        g_i, l_i, _ = gfn(flat, x[i : i + 1], y[i : i + 1])
        g_sum = g_sum + g_i
        loss_sum += float(l_i)
    import numpy as np

    np.testing.assert_allclose(g_full, g_sum, rtol=1e-4, atol=1e-5)
    assert abs(float(loss_full) - loss_sum) < 1e-3


@pytest.mark.parametrize("batch", [1, 8])
def test_lowering_small_batches(batch):
    m = M.build("mnist_conv")
    arts = aot.lower_model(m, batch=batch)
    assert f"f32[{batch},28,28,1]" in arts["grad"] or f"f32[{batch},28,28,1]" in arts["eval"]
