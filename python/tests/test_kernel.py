"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py.  This is
the CORE correctness signal for the compute layer — everything the Rust
runtime executes flows through these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d, matmul, maxpool2
from compile.kernels.matmul import _matmul_impl
from compile.kernels.ref import conv2d_ref, matmul_ref, maxpool2_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------- matmul --


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_random_shapes(m, k, n, seed):
    x = rand(seed, (m, k))
    w = rand(seed + 1, (k, n))
    np.testing.assert_allclose(matmul(x, w), matmul_ref(x, w), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),
        (128, 128, 128),  # exactly one tile
        (129, 64, 129),  # one row/col past a tile boundary
        (127, 25, 10),  # partial tiles on both axes (conv shapes)
        (32, 2304, 10),  # the FC layer of mnist_conv
        (256, 17, 3),
    ],
)
def test_matmul_tile_boundaries(m, k, n):
    x = rand(0, (m, k))
    w = rand(1, (k, n))
    np.testing.assert_allclose(matmul(x, w), matmul_ref(x, w), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block_m", [8, 64, 128])
def test_matmul_explicit_small_blocks_force_grid(block_m):
    """The multi-block grid path (used when shapes exceed the VMEM budget)
    must agree with the reference even though the default policy picks
    grid=1 for model-zoo shapes."""
    x = rand(4, (300, 20))
    w = rand(5, (20, 40))
    out = _matmul_impl(x, w, block_m=block_m)
    np.testing.assert_allclose(out, matmul_ref(x, w), rtol=1e-5, atol=1e-5)


def test_pick_block_m_policy():
    from compile.kernels.matmul import pick_block_m, VMEM_X_BUDGET

    # fits budget -> single block covering M (8-aligned)
    assert pick_block_m(25088, 75) == 25088
    assert pick_block_m(30, 10) == 32
    # beyond budget -> capped by VMEM
    big_k = 10_000
    bm = pick_block_m(1_000_000, big_k)
    assert bm * big_k * 4 <= VMEM_X_BUDGET + 8 * big_k * 4
    assert bm % 8 == 0


def test_matmul_zero_inputs():
    x = jnp.zeros((33, 7))
    w = jnp.zeros((7, 5))
    np.testing.assert_array_equal(matmul(x, w), jnp.zeros((33, 5)))


def test_matmul_bf16_inputs_accumulate_f32():
    x = rand(2, (64, 32)).astype(jnp.bfloat16)
    w = rand(3, (32, 16)).astype(jnp.bfloat16)
    out = _matmul_impl(x.astype(jnp.float32), w.astype(jnp.float32))
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(out, matmul_ref(x, w), rtol=2e-2, atol=2e-2)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 48), k=st.integers(1, 24), n=st.integers(1, 24))
def test_matmul_gradients_match_ref(m, k, n):
    """custom_vjp backward (Pallas) == autodiff of the jnp reference."""
    x = rand(10, (m, k))
    w = rand(11, (k, n))

    def f_kernel(x, w):
        return jnp.sum(jnp.sin(matmul(x, w)))

    def f_ref(x, w):
        return jnp.sum(jnp.sin(matmul_ref(x, w)))

    gx_k, gw_k = jax.grad(f_kernel, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx_k, gx_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gw_k, gw_r, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------- conv2d --


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 4),
    hw=st.integers(6, 16),
    c=st.sampled_from([1, 3]),
    f=st.sampled_from([4, 16]),
    kk=st.sampled_from([3, 5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref(b, hw, c, f, kk, seed):
    x = rand(seed, (b, hw, hw, c))
    w = rand(seed + 1, (kk, kk, c, f))
    bias = rand(seed + 2, (f,))
    np.testing.assert_allclose(
        conv2d(x, w, bias), conv2d_ref(x, w, bias), rtol=1e-4, atol=1e-4
    )


def test_conv2d_paper_shapes_mnist():
    """The paper's exact layer: 28x28x1, 16 filters of 5x5."""
    x = rand(7, (2, 28, 28, 1))
    w = rand(8, (5, 5, 1, 16))
    b = rand(9, (16,))
    out = conv2d(x, w, b)
    assert out.shape == (2, 24, 24, 16)
    np.testing.assert_allclose(out, conv2d_ref(x, w, b), rtol=1e-4, atol=1e-4)


def test_conv2d_gradients_match_ref():
    x = rand(20, (2, 10, 10, 3))
    w = rand(21, (3, 3, 3, 4))
    b = rand(22, (4,))

    def f_kernel(x, w, b):
        return jnp.sum(conv2d(x, w, b) ** 2)

    def f_ref(x, w, b):
        return jnp.sum(conv2d_ref(x, w, b) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, r in zip(gk, gr):
        np.testing.assert_allclose(a, r, rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------- maxpool --


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.sampled_from([2, 4, 8, 12, 24]),
    c=st.sampled_from([1, 4, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_maxpool_matches_ref(b, h, c, seed):
    x = rand(seed, (b, h, h, c))
    np.testing.assert_allclose(maxpool2(x), maxpool2_ref(x))


def test_maxpool_selects_max():
    x = jnp.arange(16.0).reshape(1, 4, 4, 1)
    out = maxpool2(x)
    np.testing.assert_array_equal(out[0, :, :, 0], jnp.array([[5.0, 7.0], [13.0, 15.0]]))
